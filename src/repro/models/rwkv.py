"""RWKV-6 language model (attention-free; long_500k-capable)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.parallel import act_sharding as act
from repro.layers import rwkv6
from repro.layers.common import apply_norm, embed_init, norm_init, softcap
from repro.layers.mplinear import linear_init


def _rwkv_cfg(cfg: ModelConfig) -> rwkv6.RWKVConfig:
    return rwkv6.RWKVConfig(cfg.d_model, cfg.n_heads, cfg.d_ff)


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kb, kh = jax.random.split(key, 3)
    rc = _rwkv_cfg(cfg)

    def block_init(bk):
        k1, = jax.random.split(bk, 1)
        return {
            "ln1": norm_init("ln", cfg.d_model, dtype),
            "ln2": norm_init("ln", cfg.d_model, dtype),
            "mix": rwkv6.init(k1, rc, dtype),
        }

    params = {
        "embed": {"w": embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                  dtype)},
        "ln_in": norm_init("ln", cfg.d_model, dtype),
        "blocks": jax.vmap(block_init)(jax.random.split(kb, cfg.n_layers)),
        "final_norm": norm_init("ln", cfg.d_model, dtype),
        "lm_head": linear_init(kh, cfg.d_model, cfg.padded_vocab, False,
                               dtype),
    }
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0,
               dtype=jnp.bfloat16):
    """State is O(1) in sequence length (max_len unused)."""
    rc = _rwkv_cfg(cfg)
    s = rwkv6.init_state(batch, rc, jnp.dtype(cfg.compute_dtype))
    return rwkv6.RWKVState(*(jnp.broadcast_to(a, (cfg.n_layers,) + a.shape)
                             for a in s))


def _run(params, cfg: ModelConfig, x, states, single_step: bool):
    policy = get_policy(cfg.precision_policy)
    rc = _rwkv_cfg(cfg)

    def step(carry, xs):
        h = act.batch_seq(carry)
        bp, st = xs
        hn = apply_norm("ln", h, bp["ln1"])
        if single_step:
            a, st = rwkv6.time_mix_step(bp["mix"], rc, hn, st, policy,
                                        "block/mix")
        else:
            a, st = rwkv6.time_mix(bp["mix"], rc, hn, st, policy,
                                   "block/mix")
        h = h + a
        hn = apply_norm("ln", h, bp["ln2"])
        c, st = rwkv6.channel_mix(bp["mix"], rc, hn, st, policy,
                                  "block/mix", single_step=single_step)
        return h + c, st

    fn = step
    if cfg.remat != "none" and not single_step:
        fn = jax.checkpoint(step)
    x, new_states = jax.lax.scan(fn, x, (params["blocks"], states))
    return x, new_states


def _embed(params, cfg, tokens):
    x = jnp.take(params["embed"]["w"], tokens, axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    return apply_norm("ln", x, params["ln_in"])


def _head(params, cfg, x):
    logits = jnp.dot(x, params["lm_head"]["w"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return act.logits(logits)


def train_logits(params, cfg: ModelConfig, tokens):
    x = _embed(params, cfg, tokens)
    states = init_cache(cfg, tokens.shape[0])
    x, _ = _run(params, cfg, x, states, single_step=False)
    x = apply_norm("ln", x, params["final_norm"])
    return _head(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    from repro.models.losses import fused_chunked_xent
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x = _embed(params, cfg, inp)
    states = init_cache(cfg, inp.shape[0])
    x, _ = _run(params, cfg, x, states, single_step=False)
    x = apply_norm("ln", x, params["final_norm"])
    mask = batch.get("mask")
    loss, m = fused_chunked_xent(
        x, lambda xc: _head(params, cfg, xc), tgt,
        mask[:, 1:] if mask is not None else None)
    return loss, {**m, "aux": jnp.zeros(())}


def prefill(params, cfg: ModelConfig, tokens, states):
    x = _embed(params, cfg, tokens)
    x, new_states = _run(params, cfg, x, states, single_step=False)
    x = apply_norm("ln", x[:, -1:], params["final_norm"])
    return _head(params, cfg, x)[:, 0], new_states


def decode_step(params, cfg: ModelConfig, token, pos, states):
    x = _embed(params, cfg, token)
    x, new_states = _run(params, cfg, x, states, single_step=True)
    x = apply_norm("ln", x, params["final_norm"])
    return _head(params, cfg, x)[:, 0], new_states
