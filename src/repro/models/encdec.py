"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_frames, frontend_dim); a
linear projector maps them into the encoder. Encoder blocks are
bidirectional; decoder blocks are causal self-attention + cross-attention
into the encoder output.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.parallel import act_sharding as act
from repro.layers import attention, mlp
from repro.layers.attention import AttnConfig, KVCache
from repro.layers.common import apply_norm, embed_init, norm_init, softcap
from repro.layers.mplinear import linear_init


def _self_cfg(cfg: ModelConfig, causal: bool) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        rope_theta=cfg.rope_theta, causal=causal)


def _cross_cfg(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        causal=False, cross=True)


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attention.init(k1, _self_cfg(cfg, False), dtype),
        "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp.init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attention.init(k1, _self_cfg(cfg, True), dtype),
        "ln_x": norm_init(cfg.norm, cfg.d_model, dtype),
        "xattn": attention.init(k2, _cross_cfg(cfg), dtype),
        "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp.init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    ke, kp, k1, k2, kh = jax.random.split(key, 5)
    return {
        "embed": {"w": embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                  dtype)},
        "frontend_proj": linear_init(kp, cfg.frontend_dim or cfg.d_model,
                                     cfg.d_model, True, dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
            jax.random.split(k1, n_enc)),
        "enc_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
            jax.random.split(k2, cfg.n_layers)),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "lm_head": linear_init(kh, cfg.d_model, cfg.padded_vocab, False,
                               dtype),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T, frontend_dim) stub embeddings -> (B, T, d)."""
    policy = get_policy(cfg.precision_policy)
    from repro.layers.mplinear import mp_linear
    x = mp_linear(params["frontend_proj"], frames.astype(
        jnp.dtype(cfg.compute_dtype)), policy.spec_for("frontend_proj"), path="frontend_proj")
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def step(h, bp):
        hn = apply_norm(cfg.norm, h, bp["ln1"])
        a = attention.forward(bp["attn"], _self_cfg(cfg, False), hn,
                              positions, policy, "enc/attn")
        h = h + a
        hn = apply_norm(cfg.norm, h, bp["ln2"])
        return h + mlp.forward(bp["mlp"], hn, policy, "enc/mlp", cfg.act), \
            None

    fn = jax.checkpoint(step) if cfg.remat != "none" else step
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return apply_norm(cfg.norm, x, params["enc_norm"])


def _dec_run(params, cfg, x, positions, enc_out, mode, caches, pos):
    policy = get_policy(cfg.precision_policy)

    def step(h, xs):
        bp, gc = xs
        hn = apply_norm(cfg.norm, h, bp["ln1"])
        if mode == "train":
            a = attention.forward(bp["attn"], _self_cfg(cfg, True), hn,
                                  positions, policy, "dec/attn")
            nc = gc
        elif mode == "prefill":
            a, nc = attention.prefill(bp["attn"], _self_cfg(cfg, True), hn,
                                      positions, gc, policy, "dec/attn")
        else:
            a, nc = attention.decode_step(bp["attn"], _self_cfg(cfg, True),
                                          hn, pos, gc, policy, "dec/attn")
        h = h + a
        hn = apply_norm(cfg.norm, h, bp["ln_x"])
        xa = attention.forward(bp["xattn"], _cross_cfg(cfg), hn, positions,
                               policy, "dec/xattn", kv_input=enc_out)
        h = h + xa
        hn = apply_norm(cfg.norm, h, bp["ln2"])
        h = h + mlp.forward(bp["mlp"], hn, policy, "dec/mlp", cfg.act)
        return h, nc

    fn = step
    if cfg.remat != "none" and mode == "train":
        fn = jax.checkpoint(step)
    x, new_caches = jax.lax.scan(fn, x, (params["dec_blocks"], caches))
    return x, new_caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    c = attention.init_cache(batch, max_len, _self_cfg(cfg, True), dtype)
    return KVCache(*(jnp.broadcast_to(a, (cfg.n_layers,) + a.shape)
                     for a in c))


def _logits(params, cfg, x):
    logits = jnp.dot(x, params["lm_head"]["w"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return act.logits(logits)


def train_logits(params, cfg: ModelConfig, tokens, frames):
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x, _ = _dec_run(params, cfg, x, positions, enc_out, "train", None,
                    None)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    from repro.models.losses import fused_chunked_xent
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(params, cfg, batch["frames"])
    b, s = inp.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"]["w"], inp, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x, _ = _dec_run(params, cfg, x, positions, enc_out, "train", None,
                    None)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    loss, m = fused_chunked_xent(x, lambda xc: _logits(params, cfg, xc),
                                 tgt)
    return loss, {**m, "aux": jnp.zeros(())}


def prefill(params, cfg: ModelConfig, tokens, caches, frames):
    """Returns (logits, (kv caches, encoder output)) — the encoder output
    is part of decode state for cross-attention."""
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x, new_caches = _dec_run(params, cfg, x, positions, enc_out, "prefill",
                             caches, None)
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"])
    return _logits(params, cfg, x)[:, 0], (new_caches, enc_out)


def decode_step(params, cfg: ModelConfig, token, pos, state):
    caches, enc_out = state
    x = jnp.take(params["embed"]["w"], token, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x, new_caches = _dec_run(params, cfg, x, pos[:, None], enc_out,
                             "decode", caches, pos)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return _logits(params, cfg, x)[:, 0], (new_caches, enc_out)
