"""Decoder-only transformer LM covering the dense + MoE + VLM-backbone
architectures of the zoo (qwen2, gemma2, stablelm, glm4, mixtral,
qwen3-moe, internvl2 backbone, seamless decoder reuse).

Homogeneous blocks are stacked and scanned (jax.lax.scan) so HLO size,
compile time, and remat policy are O(1) in depth; heterogeneous attention
patterns (gemma-2 local/global alternation) scan over repeating *groups*
of blocks. KV caches are stacked along the group axis and threaded as
scan xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.layers import attention, mlp, moe
from repro.layers.attention import AttnConfig, KVCache
from repro.layers.common import apply_norm, embed_init, norm_init, softcap
from repro.layers.mplinear import linear_init
from repro.parallel import act_sharding as act


def group_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.attn_pattern == "full":
        return ("full",)
    if cfg.attn_pattern == "swa":
        return ("swa",)
    if cfg.attn_pattern == "alt_local_global":
        return ("swa", "full")
    raise ValueError(cfg.attn_pattern)


def attn_cfg(cfg: ModelConfig, kind: str) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim_,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta,
        rotary_pct=cfg.rotary_pct,
        window=cfg.window if kind == "swa" else None,
        attn_softcap=cfg.attn_softcap,
        causal=True,
        scale=cfg.attn_scale,
    )


def moe_cfg(cfg: ModelConfig) -> moe.MoEConfig:
    return moe.MoEConfig(cfg.d_model, cfg.moe.d_expert, cfg.moe.n_experts,
                         cfg.moe.top_k, cfg.moe.capacity_factor, cfg.act,
                         dispatch=cfg.moe.dispatch)


def _block_init(key, cfg: ModelConfig, kind: str, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attention.init(k1, attn_cfg(cfg, kind), dtype),
        "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.moe:
        p["moe"] = moe.init(k2, moe_cfg(cfg), dtype)
    else:
        p["mlp"] = mlp.init(k3, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_norms:
        p["post_ln1"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["post_ln2"] = norm_init(cfg.norm, cfg.d_model, dtype)
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    kinds = group_kinds(cfg)
    assert cfg.n_layers % len(kinds) == 0, (cfg.arch_id, kinds)
    n_groups = cfg.n_layers // len(kinds)
    ke, kb, kh = jax.random.split(key, 3)

    def group_init(gk):
        sub = jax.random.split(gk, len(kinds))
        return {f"b{i}": _block_init(sub[i], cfg, kind, dtype)
                for i, kind in enumerate(kinds)}

    params = {
        "embed": {"w": embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                  dtype)},
        "blocks": jax.vmap(group_init)(jax.random.split(kb, n_groups)),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tied_embeddings:
        params["lm_head"] = linear_init(kh, cfg.d_model, cfg.padded_vocab,
                                        False, dtype)
    return params


def _embed(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"]["w"], tokens, axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    if cfg.norm == "rms_zc":  # gemma convention: scale by sqrt(d)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return act.batch_seq(x)


def _head(params, cfg: ModelConfig, x):
    if cfg.tied_embeddings:
        w = params["embed"]["w"]
        logits = jnp.dot(x, w.T.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    else:
        w = params["lm_head"]["w"]
        logits = jnp.dot(x, w.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:  # mask the padding columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return act.logits(logits)


def _apply_block(params, cfg: ModelConfig, kind: str, x, positions,
                 policy, mode: str, cache: Optional[KVCache], pos,
                 valid=None):
    path = f"block/{kind}/attn"
    acfg = attn_cfg(cfg, kind)
    h = apply_norm(cfg.norm, x, params["ln1"])
    new_cache = cache
    if mode == "train":
        a = attention.forward(params["attn"], acfg, h, positions, policy,
                              path)
    elif mode == "prefill":
        a, new_cache = attention.prefill(params["attn"], acfg, h,
                                         positions, cache, policy, path)
    elif mode == "chunk":
        a, new_cache = attention.prefill_chunk(params["attn"], acfg, h,
                                               positions, valid, cache,
                                               policy, path)
    else:
        a, new_cache = attention.decode_step(params["attn"], acfg, h, pos,
                                             cache, policy, path)
    if cfg.post_norms:
        a = apply_norm(cfg.norm, a, params["post_ln1"])
    x = x + a
    h = apply_norm(cfg.norm, x, params["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        f, aux = moe.forward(params["moe"], moe_cfg(cfg), h, policy,
                             "block/moe")
    else:
        f = mlp.forward(params["mlp"], h, policy, "block/mlp", cfg.act)
    if cfg.post_norms:
        f = apply_norm(cfg.norm, f, params["post_ln2"])
    return x + f, new_cache, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def _run_blocks(params, cfg: ModelConfig, x, positions, mode: str,
                caches=None, pos=None, valid=None):
    policy = get_policy(cfg.precision_policy)
    kinds = group_kinds(cfg)

    def group_step(carry, xs):
        h, aux = carry
        h = act.batch_seq(h)  # pin the scan-carry layout (SP)
        gp, gc = xs
        new_gc = {}
        for i, kind in enumerate(kinds):
            c_i = gc[f"b{i}"] if gc is not None else None
            h, nc, a = _apply_block(gp[f"b{i}"], cfg, kind, h, positions,
                                    policy, mode, c_i, pos, valid=valid)
            new_gc[f"b{i}"] = nc
            aux = aux + a
        return (h, aux), new_gc

    step = _remat_wrap(group_step, cfg) if mode == "train" else group_step
    xs = (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                        xs)
    return x, aux, (new_caches if caches is not None else None)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Stacked (n_groups, ...) caches. SWA blocks get window-sized ring
    buffers — the reason long_500k fits for swa archs."""
    kinds = group_kinds(cfg)
    n_groups = cfg.n_layers // len(kinds)

    def one(kind):
        cap = max_len
        if kind == "swa" and cfg.window is not None:
            cap = min(cfg.window, max_len)
        c = attention.init_cache(batch, cap, attn_cfg(cfg, kind), dtype)
        return KVCache(*(jnp.broadcast_to(a, (n_groups,) + a.shape)
                         for a in c))

    return {f"b{i}": one(kind) for i, kind in enumerate(kinds)}


def train_logits(params, cfg: ModelConfig, tokens):
    """tokens: (B, S) -> logits (B, S, V) f32, aux loss."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _embed(params, cfg, tokens)
    x, aux, _ = _run_blocks(params, cfg, x, positions, "train")
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return _head(params, cfg, x), aux


def hidden_states(params, cfg: ModelConfig, tokens):
    """Final normed hidden states (B, S, d) + aux loss (fused-loss path)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _embed(params, cfg, tokens)
    x, aux, _ = _run_blocks(params, cfg, x, positions, "train")
    return apply_norm(cfg.norm, x, params["final_norm"]), aux


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: {'tokens': (B, S+1) int32} next-token xent (mean/token).

    Uses the fused chunked head+loss: the (B, S, V) logits never
    materialize (see losses.fused_chunked_xent)."""
    from repro.models.losses import fused_chunked_xent
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x, aux = hidden_states(params, cfg, inp)
    mask = batch.get("mask")
    loss, m = fused_chunked_xent(
        x, lambda xc: _head(params, cfg, xc), tgt,
        mask[:, 1:] if mask is not None else None)
    return loss + 0.01 * aux, {**m, "aux": aux}


def prefill(params, cfg: ModelConfig, tokens, caches):
    """tokens: (B, S) -> (last-position logits (B, V), new caches)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = _embed(params, cfg, tokens)
    x, _, new_caches = _run_blocks(params, cfg, x, positions, "prefill",
                                   caches=caches)
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"])
    return _head(params, cfg, x)[:, 0], new_caches


def prefill_chunk(params, cfg: ModelConfig, tokens, offsets, lengths,
                  caches):
    """Position-offset prefill continuation for the continuous engine.

    tokens: (B, S) one chunk of each row's prompt; offsets: (B,)
    absolute position of ``tokens[:, 0]``; lengths: (B,) valid tokens
    per row (0 = row untouched). Writes the chunk's K/V into the LIVE
    ``caches`` and returns them — no logits: the engine feeds the last
    prompt token through ``decode_step``, which computes the head."""
    b, s = tokens.shape
    positions = offsets[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    valid = jnp.arange(s, dtype=jnp.int32)[None, :] < lengths[:, None]
    x = _embed(params, cfg, jnp.where(valid, tokens, 0))
    _, _, new_caches = _run_blocks(params, cfg, x, positions, "chunk",
                                   caches=caches, valid=valid)
    return new_caches


def decode_step(params, cfg: ModelConfig, token, pos, caches):
    """token: (B, 1); pos: (B,) -> (logits (B, V), new caches)."""
    x = _embed(params, cfg, token)
    x, _, new_caches = _run_blocks(params, cfg, x, pos[:, None], "decode",
                                   caches=caches, pos=pos)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return _head(params, cfg, x)[:, 0], new_caches
