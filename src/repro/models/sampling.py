"""On-device batched token selection: temperature / top-k / top-p.

``sample_tokens`` is the single selection primitive both decode paths
share — the per-token engine jits it standalone over one step's logits,
and ``registry.make_block_decode`` closes over it inside the blocked
scan (the PRNG keys thread through the scan carry, so a block of n
steps consumes exactly n key splits per active slot — the reason
sampled streams are identical at every ``decode_block``).

All parameters are per-row (B,) arrays so one program serves a batch
mixing greedy and sampled slots: rows with ``temperature <= 0`` take
the argmax (bit-identical to the greedy program — the argmax runs on
the raw, unscaled logits), every other row samples from the
temperature-scaled, top-k/top-p-truncated distribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(keys, logits, temperature, top_k, top_p):
    """Select one token per batch row.

    keys: (B, 2) uint32 per-row PRNG keys; logits: (B, V) float;
    temperature/top_p: (B,) f32; top_k: (B,) int32 (0 = unrestricted).
    Returns ``(new_keys, tokens)`` — (B, 2) uint32 advanced keys (every
    row's key advances once per call, consumed or not, so key cadence
    never depends on which rows sample) and (B,) int32 tokens.

    Truncation follows the standard nucleus convention: tokens are
    ranked by scaled logit; a token survives while its rank is below
    ``top_k`` AND the cumulative probability *before* it is below
    ``top_p`` (the crossing token is kept, rank 0 always survives).
    """
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    order = jnp.argsort(-scaled, axis=-1)            # desc by logit
    ranked = jnp.take_along_axis(scaled, order, axis=-1)
    probs = jax.nn.softmax(ranked, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    rank = jnp.arange(v, dtype=jnp.int32)[None, :]
    keep = rank < jnp.where(top_k > 0, top_k, v)[:, None]
    keep &= (cum - probs) < top_p[:, None]
    keep |= rank == 0
    ranked = jnp.where(keep, ranked, -jnp.inf)

    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    idx = jax.vmap(jax.random.categorical)(split[:, 1], ranked)
    sampled = jnp.take_along_axis(order, idx[:, None], axis=-1)[:, 0]
    tokens = jnp.where(temperature > 0.0,
                       sampled.astype(jnp.int32), greedy)
    return split[:, 0], tokens
