"""Sharding-friendly loss functions.

Next-token cross entropy WITHOUT take_along_axis: gathering along the
vocab axis forces XLA SPMD to all-gather the (B, S, V) logits (hundreds
of GB per device at train_4k scale). The logsumexp + one-hot-dot form
keeps every op elementwise/reduction over the sharded vocab axis, so the
logits stay vocab-parallel end to end.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def next_token_xent(logits: jax.Array, targets: jax.Array,
                    mask: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """logits: (B, S, V) f32; targets: (B, S) int32. Mean nats/token."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)          # (B, S)
    onehot = jax.nn.one_hot(targets, logits.shape[-1],
                            dtype=logits.dtype)                 # (B, S, V)
    tgt_logit = jnp.sum(logits * onehot, axis=-1)               # (B, S)
    nll = lse - tgt_logit
    if mask is None:
        loss = nll.mean()
    else:
        m = mask.astype(jnp.float32)
        loss = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return loss, {"nll": loss}


def fused_chunked_xent(x: jax.Array, head_fn, targets: jax.Array,
                       mask: Optional[jax.Array] = None,
                       chunk: int = 512
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused LM-head + cross entropy, chunked over the sequence.

    ``x``: (B, S, d) final hidden states; ``head_fn(x_chunk) -> logits``.
    Never materializes the full (B, S, V) logits: each chunk's logits
    exist only inside a checkpointed scan step (recomputed in backward) —
    the standard production fused-softmax-head pattern. Exact (the per-
    chunk sums are exact f32 accumulations of per-token nll terms).
    """
    b, s, d = x.shape
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        pad_mask = jnp.broadcast_to((jnp.arange(s + pad) < s)[None, :],
                                    (b, s + pad))
        mask = pad_mask if mask is None else \
            jnp.pad(mask, ((0, 0), (0, pad))) & pad_mask
    if mask is None:
        mask = jnp.ones((b, s), bool)
    sp = x.shape[1]
    nc = sp // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    def chunk_step(carry, inp):
        total, count = carry
        xc, tc, mc = inp
        logits = head_fn(xc)                                   # (B,c,V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(tc, logits.shape[-1],
                                dtype=logits.dtype)
        tgt = jnp.sum(logits * onehot, axis=-1)
        m = mc.astype(jnp.float32)
        nll = (lse - tgt) * m
        return (total + nll.sum(), count + m.sum()), None

    (total, count), _ = jax.lax.scan(
        jax.checkpoint(chunk_step),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ts, ms))
    loss = total / jnp.maximum(count, 1.0)
    return loss, {"nll": loss}
