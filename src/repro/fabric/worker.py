"""Fabric worker: a checkpoint-restored ServingEngine behind a transport.

``FabricWorker`` owns one :class:`~repro.serving.engine.ServingEngine`
and one :class:`~repro.fabric.transport.Endpoint` back to the
controller. Its ``tick()`` is the unit the whole fabric schedules in:

  1. fire the injectable ``failure_hook`` (raises
     :class:`repro.runtime.fault_tolerance.WorkerFailure` to simulate a
     died node — the same signal the training runtime injects);
  2. drain the endpoint: ``SubmitRequest`` becomes an engine submit,
     ``Drain``/``Shutdown`` flip lifecycle state;
  3. advance the engine one step when it has pending work;
  4. stream back what changed: per-request ``TokenChunk`` deltas (only
     newly generated tokens cross the wire), one ``StatsSnapshot`` (the
     engine's measured ReplicaStats feed for the router's online cost
     correction), one ``Heartbeat``.

The worker never blocks on the transport; a controller that stops
submitting simply sees heartbeats. A worker that dies raises out of
``tick()`` — in-process drivers catch it and go silent, subprocess
workers exit and the closed socket is the controller's failure signal.
Either way the controller's view is the same: heartbeats stop.

A **resumable** worker (``resumable=True``) treats a severed endpoint
as an outage, not a death: its engine keeps stepping (in-flight
requests keep generating into local state) while disconnected, and
``reconnect(endpoint)`` re-attaches it — it sends a ``Resume`` message
carrying per-rid emitted-token counts, the controller answers a
``ResumeAck`` with the counts it actually *received* plus any rids it
rerouted while the worker was gone, and the worker rewinds each live
request's stream cursor to the controller's count. Tokens the
controller already has are never re-appended (every ``TokenChunk``
carries its generation ``start`` offset); tokens lost in flight are
retransmitted. Nothing restarts from scratch.

``worker_main`` is the subprocess entry (``python -m repro.fabric
worker --ckpt DIR --connect HOST:PORT``): restore from the serve-ready
checkpoint (zero quantize/calibrate work, see fabric/checkpoint.py),
dial the controller (with jittered-exponential-backoff retry), announce,
loop. ``--register`` (no ``--ckpt``) is the fresh-host path: the worker
sends ``Register`` first and restores from whatever checkpoint
directory the controller's ``RegisterAck`` hands it. ``--resume`` makes
a dropped connection trigger redial + ``Resume`` instead of exit.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.fabric import transport as tp


class FabricWorker:
    def __init__(self, name: str, engine, endpoint: tp.Endpoint, *,
                 clock: Optional[Callable[[], float]] = None,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 resumable: bool = False):
        self.name = name
        self.engine = engine
        self.endpoint = endpoint
        self.clock = clock if clock is not None else engine.clock
        self.failure_hook = failure_hook
        self.resumable = resumable
        self.tick_count = 0
        self.draining = False
        self._shutdown = False
        # requests this worker received over the fabric that still owe
        # the controller tokens: rid -> (engine Request, tokens sent)
        self._live: Dict[int, tuple] = {}
        self._done_sent: set = set()   # done chunk emitted, unsettled
        self._retired: list = []       # FIFO of finished rids kept live
        self.reconnects = 0

    # ------------------------------------------------------------ protocol

    def announce(self) -> None:
        from repro.fabric.checkpoint import model_config_to_dict
        self.endpoint.send(tp.Hello(
            name=self.name,
            policy=self.engine.cfg.precision_policy,
            slots=self.engine.b,
            model_config=model_config_to_dict(self.engine.cfg),
            cost_correction=self.engine.config.cost_correction,
            resumable=self.resumable))

    @staticmethod
    def _generated(req) -> int:
        return 0 if req.tokens is None \
            else len(req.tokens) - len(req.prompt)

    def reconnect(self, endpoint: tp.Endpoint) -> None:
        """Re-attach after a severed connection: adopt the fresh
        endpoint and open the resume handshake with this worker's
        per-rid emitted-token ledger. The engine state was never lost —
        only the wire was."""
        if not self.resumable:
            raise RuntimeError(
                f"worker {self.name!r} is not resumable — spawn it "
                f"with resumable=True to survive severed endpoints")
        self.endpoint = endpoint
        self.reconnects += 1
        self.endpoint.send(tp.Resume(
            name=self.name,
            progress={int(rid): self._generated(req)
                      for rid, (req, _) in self._live.items()}))

    def _handle(self, msg) -> None:
        from repro.serving.config import SamplingParams
        from repro.serving.engine import Request

        if isinstance(msg, tp.SubmitRequest):
            req = Request(
                rid=msg.rid,
                prompt=np.asarray(msg.prompt, np.int32),
                max_new_tokens=msg.max_new_tokens,
                priority=msg.priority,
                tags=tuple(msg.tags),
                sampling=SamplingParams(
                    temperature=msg.temperature, top_k=msg.top_k,
                    top_p=msg.top_p, stop_ids=tuple(msg.stop_ids),
                    seed=msg.seed))
            self.engine.submit(req)
            self._live[msg.rid] = (req, 0)
        elif isinstance(msg, tp.ResumeAck):
            # rewind each live request's stream cursor to what the
            # controller actually received: anything beyond it was lost
            # in flight and will retransmit; anything at or below is
            # deduped by the cursor itself
            for rid, have in msg.progress.items():
                rid = int(rid)
                if rid in self._live:
                    req, _ = self._live[rid]
                    self._live[rid] = (req, int(have))
                    # the controller still wants this rid: if its done
                    # chunk was lost, let _stream re-emit it
                    self._done_sent.discard(rid)
            for rid in msg.cancel:
                rid = int(rid)
                self._live.pop(rid, None)
                self._done_sent.discard(rid)
                if rid in self._retired:
                    self._retired.remove(rid)
        elif isinstance(msg, tp.Drain):
            self.draining = True
        elif isinstance(msg, tp.Shutdown):
            self._shutdown = True

    # finished-but-unacknowledged retention for resumable workers: a
    # done chunk lost to a severing connection must be replayable from
    # the Resume ledger, so finished requests stay live until a
    # ResumeAck settles them (bounded — the cap only matters across
    # repeated severances)
    RETIRE_KEEP = 256

    def _stream(self) -> None:
        """Send every request's newly generated tokens as one delta
        chunk (stamped with its generation ``start`` offset so the
        receiver can dedup); a finishing request's chunk carries
        ``done`` and the finish metadata, then leaves the live set —
        resumable workers retain it until resume reconciliation
        confirms the controller is settled."""
        finished = []
        for rid, (req, sent) in self._live.items():
            if req.tokens is None:       # still queued / prefilling
                continue
            if req.done and rid in self._done_sent:
                continue
            gen = req.tokens[len(req.prompt) + sent:]
            if gen or req.done:
                self.endpoint.send(tp.TokenChunk(
                    rid=rid, tokens=[int(t) for t in gen],
                    done=req.done, finish_reason=req.finish_reason,
                    truncated=req.truncated, start=sent))
                self._live[rid] = (req, sent + len(gen))
            if req.done:
                finished.append(rid)
        for rid in finished:
            if self.resumable:
                self._done_sent.add(rid)
                if rid not in self._retired:
                    self._retired.append(rid)
                while len(self._retired) > self.RETIRE_KEEP:
                    old = self._retired.pop(0)
                    self._live.pop(old, None)
                    self._done_sent.discard(old)
            else:
                del self._live[rid]

    # ---------------------------------------------------------------- loop

    @property
    def connected(self) -> bool:
        return not self.endpoint.closed

    def tick(self) -> bool:
        """One worker scheduling quantum; returns False after Shutdown.
        Raises WorkerFailure out of an armed ``failure_hook`` — the
        caller decides whether that is a silent death (in-process
        driver) or a process exit (subprocess main). A resumable
        worker whose endpoint is severed (or severs mid-tick) keeps
        stepping its engine offline — in-flight requests keep
        generating into local state — until ``reconnect`` re-attaches
        it; a non-resumable worker raises TransportClosed as before."""
        if self.failure_hook is not None:
            self.failure_hook(self.tick_count)
        self.tick_count += 1
        if self.endpoint.closed:
            if not self.resumable:
                raise tp.TransportClosed(
                    f"worker {self.name!r} lost its controller")
            if self.engine.has_pending():
                self.engine.step()
            return True
        try:
            for msg in self.endpoint.poll():
                self._handle(msg)
            if self._shutdown:
                return False
            if self.engine.has_pending():
                self.engine.step()
            self._stream()
            self.endpoint.send(tp.StatsSnapshot(
                name=self.name, stats=self.engine.stats.snapshot(),
                slots=self.engine.b,
                completed=len(self.engine.completed)))
            self.endpoint.send(tp.Heartbeat(tick=self.tick_count,
                                            time=float(self.clock())))
            if self.draining and not self.engine.has_pending() \
                    and not self._live:
                self.endpoint.send(tp.Drained(
                    completed=len(self.engine.completed)))
                self.draining = False
        except tp.TransportClosed:
            if not self.resumable:
                raise
            # severed mid-tick: stream cursors only advance after a
            # successful send, so nothing is marked delivered that was
            # not; the engine state is intact and resume reconciles
        return True

    def run(self, idle_sleep: float = 0.002) -> None:
        while True:
            busy = self.engine.has_pending()
            if self.endpoint.closed and self.resumable:
                # surface the outage so the caller can redial and
                # reconnect() — the in-process driver path instead
                # keeps ticking through the disconnection
                raise tp.TransportClosed(
                    f"worker {self.name!r} disconnected")
            if not self.tick():
                return
            if not busy and not self.engine.has_pending():
                time.sleep(idle_sleep)      # don't spin an idle worker


def _await_register_ack(endpoint: tp.Endpoint,
                        timeout: float = 60.0) -> tp.RegisterAck:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        for msg in endpoint.poll():
            if isinstance(msg, tp.RegisterAck):
                return msg
        time.sleep(0.01)
    raise tp.TransportClosed(
        f"controller never answered Register within {timeout}s")


def worker_main(argv=None) -> int:
    """Subprocess entry: restore a serve-ready engine from a checkpoint
    and serve it over a socket back to the controller.

    ``--register`` (checkpoint handoff): dial in WITHOUT a local
    checkpoint, send ``Register``, restore from the directory the
    controller's ``RegisterAck`` names — the fresh-host deployment
    path. ``--resume``: survive a dropped controller connection by
    redialing (jittered exponential backoff) and resuming in place —
    in-flight requests keep their engine state and already-streamed
    tokens are never re-sent.
    """
    import argparse

    from repro.fabric.checkpoint import build_engine

    ap = argparse.ArgumentParser(prog="repro.fabric worker")
    ap.add_argument("--ckpt", default=None,
                    help="serve-ready checkpoint directory (omit with "
                    "--register to restore from the controller's "
                    "handoff)")
    ap.add_argument("--name", default="worker")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--register", action="store_true",
                    help="announce via Register and take the "
                    "checkpoint directory from RegisterAck")
    ap.add_argument("--resume", action="store_true",
                    help="reconnect-and-resume on a dropped "
                    "controller connection instead of exiting")
    ap.add_argument("--retry", type=int, default=8,
                    help="connection attempts (jittered exponential "
                    "backoff between them)")
    ap.add_argument("--seed", type=int, default=0,
                    help="backoff jitter seed")
    args = ap.parse_args(argv)
    if args.ckpt is None and not args.register:
        ap.error("--ckpt is required unless --register is given")

    host, port = args.connect.rsplit(":", 1)
    port = int(port)
    endpoint = tp.connect_with_retry(host, port, attempts=args.retry,
                                     seed=args.seed)
    ckpt, step = args.ckpt, args.step
    if args.register:
        endpoint.send(tp.Register(name=args.name,
                                  need_checkpoint=ckpt is None))
        if ckpt is None:
            ack = _await_register_ack(endpoint)
            ckpt, step = ack.ckpt_dir, ack.step
    engine = build_engine(ckpt, step)
    worker = FabricWorker(args.name, engine, endpoint,
                          resumable=args.resume)
    worker.announce()
    try:
        while True:
            try:
                worker.run()
                return 0                  # orderly Shutdown
            except (tp.TransportClosed, tp.ProtocolError):
                if not args.resume:
                    return 0              # controller went away
            endpoint.close()
            try:
                endpoint = tp.connect_with_retry(
                    host, port, attempts=args.retry, seed=args.seed)
            except tp.TransportClosed:
                return 0                  # controller really is gone
            worker.reconnect(endpoint)
    finally:
        endpoint.close()
    return 0
