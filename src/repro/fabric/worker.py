"""Fabric worker: a checkpoint-restored ServingEngine behind a transport.

``FabricWorker`` owns one :class:`~repro.serving.engine.ServingEngine`
and one :class:`~repro.fabric.transport.Endpoint` back to the
controller. Its ``tick()`` is the unit the whole fabric schedules in:

  1. fire the injectable ``failure_hook`` (raises
     :class:`repro.runtime.fault_tolerance.WorkerFailure` to simulate a
     died node — the same signal the training runtime injects);
  2. drain the endpoint: ``SubmitRequest`` becomes an engine submit,
     ``Drain``/``Shutdown`` flip lifecycle state;
  3. advance the engine one step when it has pending work;
  4. stream back what changed: per-request ``TokenChunk`` deltas (only
     newly generated tokens cross the wire), one ``StatsSnapshot`` (the
     engine's measured ReplicaStats feed for the router's online cost
     correction), one ``Heartbeat``.

The worker never blocks on the transport; a controller that stops
submitting simply sees heartbeats. A worker that dies raises out of
``tick()`` — in-process drivers catch it and go silent, subprocess
workers exit and the closed socket is the controller's failure signal.
Either way the controller's view is the same: heartbeats stop.

``worker_main`` is the subprocess entry (``python -m repro.fabric
worker --ckpt DIR --connect HOST:PORT``): restore from the serve-ready
checkpoint (zero quantize/calibrate work, see fabric/checkpoint.py),
dial the controller, announce, loop.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.fabric import transport as tp


class FabricWorker:
    def __init__(self, name: str, engine, endpoint: tp.Endpoint, *,
                 clock: Optional[Callable[[], float]] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.name = name
        self.engine = engine
        self.endpoint = endpoint
        self.clock = clock if clock is not None else engine.clock
        self.failure_hook = failure_hook
        self.tick_count = 0
        self.draining = False
        self._shutdown = False
        # requests this worker received over the fabric that still owe
        # the controller tokens: rid -> (engine Request, tokens sent)
        self._live: Dict[int, tuple] = {}

    # ------------------------------------------------------------ protocol

    def announce(self) -> None:
        from repro.fabric.checkpoint import model_config_to_dict
        self.endpoint.send(tp.Hello(
            name=self.name,
            policy=self.engine.cfg.precision_policy,
            slots=self.engine.b,
            model_config=model_config_to_dict(self.engine.cfg),
            cost_correction=self.engine.config.cost_correction))

    def _handle(self, msg) -> None:
        from repro.serving.config import SamplingParams
        from repro.serving.engine import Request

        if isinstance(msg, tp.SubmitRequest):
            req = Request(
                rid=msg.rid,
                prompt=np.asarray(msg.prompt, np.int32),
                max_new_tokens=msg.max_new_tokens,
                priority=msg.priority,
                tags=tuple(msg.tags),
                sampling=SamplingParams(
                    temperature=msg.temperature, top_k=msg.top_k,
                    top_p=msg.top_p, stop_ids=tuple(msg.stop_ids),
                    seed=msg.seed))
            self.engine.submit(req)
            self._live[msg.rid] = (req, 0)
        elif isinstance(msg, tp.Drain):
            self.draining = True
        elif isinstance(msg, tp.Shutdown):
            self._shutdown = True

    def _stream(self) -> None:
        """Send every request's newly generated tokens as one delta
        chunk; a finishing request's chunk carries ``done`` and the
        finish metadata, then leaves the live set."""
        finished = []
        for rid, (req, sent) in self._live.items():
            if req.tokens is None:       # still queued / prefilling
                continue
            gen = req.tokens[len(req.prompt) + sent:]
            if gen or req.done:
                self.endpoint.send(tp.TokenChunk(
                    rid=rid, tokens=[int(t) for t in gen],
                    done=req.done, finish_reason=req.finish_reason,
                    truncated=req.truncated))
                self._live[rid] = (req, sent + len(gen))
            if req.done:
                finished.append(rid)
        for rid in finished:
            del self._live[rid]

    # ---------------------------------------------------------------- loop

    def tick(self) -> bool:
        """One worker scheduling quantum; returns False after Shutdown.
        Raises WorkerFailure out of an armed ``failure_hook`` — the
        caller decides whether that is a silent death (in-process
        driver) or a process exit (subprocess main)."""
        if self.failure_hook is not None:
            self.failure_hook(self.tick_count)
        self.tick_count += 1
        for msg in self.endpoint.poll():
            self._handle(msg)
        if self._shutdown:
            return False
        if self.engine.has_pending():
            self.engine.step()
        self._stream()
        self.endpoint.send(tp.StatsSnapshot(
            name=self.name, stats=self.engine.stats.snapshot(),
            slots=self.engine.b, completed=len(self.engine.completed)))
        self.endpoint.send(tp.Heartbeat(tick=self.tick_count,
                                        time=float(self.clock())))
        if self.draining and not self.engine.has_pending() \
                and not self._live:
            self.endpoint.send(tp.Drained(
                completed=len(self.engine.completed)))
            self.draining = False
        return True

    def run(self, idle_sleep: float = 0.002) -> None:
        while True:
            busy = self.engine.has_pending()
            if not self.tick():
                return
            if not busy and not self.engine.has_pending():
                time.sleep(idle_sleep)      # don't spin an idle worker


def worker_main(argv=None) -> int:
    """Subprocess entry: restore a serve-ready engine from a checkpoint
    and serve it over a socket back to the controller."""
    import argparse

    from repro.fabric.checkpoint import build_engine

    ap = argparse.ArgumentParser(prog="repro.fabric worker")
    ap.add_argument("--ckpt", required=True,
                    help="serve-ready checkpoint directory")
    ap.add_argument("--name", default="worker")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--step", type=int, default=None)
    args = ap.parse_args(argv)

    host, port = args.connect.rsplit(":", 1)
    endpoint = tp.connect(host, int(port))
    engine = build_engine(args.ckpt, args.step)
    worker = FabricWorker(args.name, engine, endpoint)
    worker.announce()
    try:
        worker.run()
    except tp.TransportClosed:
        pass                # controller went away: orderly exit
    finally:
        endpoint.close()
    return 0
