"""``python -m repro.fabric chaos`` — the chaos-hardening CI contract.

One seeded :class:`~repro.fabric.chaos.FaultSchedule` per worker, a
ManualClock, and a 3-worker fleet restored from one serve-ready
checkpoint. Everything that goes wrong is deterministic, and nothing
that goes wrong may change what callers observe:

  * **combined chaos** — worker-a's telemetry is dropped, duplicated
    and split across delivery quanta, and its heartbeats stall through
    a window (suspect -> recover, no rework); worker-b suffers a
    connection reset mid-flight (transient partition) and resumes IN
    PLACE via the Resume handshake; worker-c dies silently at a
    scheduled tick (permanent kill) and its work requeues. The run must
    complete with zero request loss and token streams identical to a
    single-engine reference.
  * **transient partition, isolated** — a two-worker fleet where the
    only fault is worker-b's severed link. Recovery must go through
    Resume, not requeue: ``scheduler.requeued == 0``, ``resumed == 1``,
    no failures, identical streams. Run twice with the same seed, the
    delivery traces and streams must be bit-identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
from typing import Dict, Optional

from repro.fabric.smoke import (POLICY, _engine_streams, _make_requests,
                                _streams)


def _spawn_chaos_worker(ctrl, ckpt: str, name: str, *,
                        schedule=None, resumable: bool = False):
    """spawn_local_worker with the worker-side endpoint wrapped in a
    ChaosEndpoint (faults apply to the worker -> controller direction,
    where the token stream lives). Returns (worker, handle, endpoint)
    so the harness can reattach and read the delivery trace."""
    from repro.fabric import transport as tp
    from repro.fabric.chaos import ChaosEndpoint, fail_at
    from repro.fabric.checkpoint import build_engine
    from repro.fabric.controller import LocalWorkerDriver
    from repro.fabric.worker import FabricWorker

    ctrl_ep, worker_ep = tp.local_pair()
    hook = None
    if schedule is not None:
        worker_ep = ChaosEndpoint(worker_ep, schedule, ctrl.clock)
        hook = fail_at(schedule.kill_at_tick)
    engine = build_engine(ckpt, clock=ctrl.clock)
    worker = FabricWorker(name, engine, worker_ep, clock=ctrl.clock,
                          failure_hook=hook, resumable=resumable)
    worker.announce()
    ctrl.add_worker(ctrl_ep, driver=LocalWorkerDriver(worker), name=name)
    return worker, ctrl.workers[name], worker_ep


def _drive(ctrl, clock, *, reattach: Optional[Dict] = None,
           max_ticks: int = 10_000) -> Dict[str, int]:
    """Tick the fleet to drained, healing each worker in ``reattach``
    (name -> FabricWorker) the moment the controller suspects it.
    Returns how many in-flight requests each healed worker was holding
    at reattach time — the work that must resume, not requeue."""
    from repro.fabric.controller import reattach_local_worker

    pending = dict(reattach or {})
    held: Dict[str, int] = {}
    ticks = 0
    while ctrl.has_pending():
        clock.advance(1.0)
        ctrl.tick()
        ticks += 1
        for name in list(pending):
            h = ctrl.workers[name]
            if h.state == "suspect" and h.endpoint.closed:
                held[name] = len(h.replica.in_flight)
                reattach_local_worker(ctrl, pending.pop(name))
        if ticks > max_ticks:
            raise RuntimeError(
                f"chaos fleet did not drain in {max_ticks} ticks")
    return held


def _run_combined(ckpt: str, reqs, seed: int, kill_tick: int):
    """Drops + duplicates + partial writes + heartbeat stall on
    worker-a, transient partition on worker-b, silent kill on
    worker-c — one schedule set, one run."""
    from repro.fabric.chaos import FaultSchedule
    from repro.fabric.controller import Controller, ManualClock

    clock = ManualClock()
    ctrl = Controller(heartbeat_timeout=4.0, clock=clock)
    # telemetry hostility: StatsSnapshot drops keep heartbeat gaps
    # bounded (the stall window alone drives suspicion, never death)
    _, _, ep_a = _spawn_chaos_worker(
        ctrl, ckpt, "worker-a",
        schedule=FaultSchedule(seed=seed, drop_rate=0.3,
                               droppable=("StatsSnapshot",),
                               duplicate_every=3, partial_every=4,
                               stall_heartbeats_between=(6.0, 10.0)))
    wb, _, ep_b = _spawn_chaos_worker(
        ctrl, ckpt, "worker-b",
        schedule=FaultSchedule(seed=seed, reset_at_msg=12),
        resumable=True)
    _spawn_chaos_worker(
        ctrl, ckpt, "worker-c",
        schedule=FaultSchedule(seed=seed, kill_at_tick=kill_tick))
    for r in reqs:
        ctrl.submit(r)
    held = _drive(ctrl, clock, reattach={"worker-b": wb})
    return ctrl, held, ep_a, ep_b


def _run_partition(ckpt: str, reqs, seed: int):
    """The isolated resume path: the ONLY fault is worker-b's severed
    connection; recovery must not touch the requeue machinery."""
    from repro.fabric.chaos import FaultSchedule
    from repro.fabric.controller import (Controller, ManualClock,
                                         spawn_local_worker)

    clock = ManualClock()
    ctrl = Controller(heartbeat_timeout=4.0, clock=clock)
    spawn_local_worker(ctrl, ckpt, name="worker-a")
    wb, _, ep_b = _spawn_chaos_worker(
        ctrl, ckpt, "worker-b",
        schedule=FaultSchedule(seed=seed, reset_at_msg=12),
        resumable=True)
    for r in reqs:
        ctrl.submit(r)
    held = _drive(ctrl, clock, reattach={"worker-b": wb})
    return ctrl, held, ep_b


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.fabric chaos")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-tick", type=int, default=3)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import reduced
    from repro.fabric.checkpoint import build_engine, save_engine_checkpoint
    from repro.models import registry
    from repro.serving.config import EngineConfig
    from repro.serving.engine import ServingEngine

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy=POLICY)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    config = EngineConfig(batch_slots=args.slots, cache_len=64,
                          act_calibration="auto",
                          cost_correction="online")
    engine = ServingEngine(cfg, api, params, config=config)

    with tempfile.TemporaryDirectory(prefix="chaos_smoke_") as tmp:
        ckpt = os.path.join(tmp, "ckpt")
        save_engine_checkpoint(engine, ckpt, step=0)
        ref = _engine_streams(
            build_engine(ckpt, api=api),
            _make_requests(cfg, args.requests, args.max_new, args.seed))

        # -- combined chaos: drops + partition + silent kill, one run
        reqs = _make_requests(cfg, args.requests, args.max_new,
                              args.seed)
        ctrl, held, ep_a, ep_b = _run_combined(ckpt, reqs, args.seed,
                                               args.kill_tick)
        assert len(ctrl.completed) == args.requests, (
            f"chaos lost requests: have {sorted(ctrl.completed)}")
        assert _streams(ctrl.completed) == ref, (
            "chaos changed token streams")
        assert ctrl.failures == ["worker-c"], ctrl.failures
        assert ctrl.scheduler.requeued > 0, (
            "the killed worker held nothing — kill tick not mid-flight")
        assert ctrl.resumed == 1, ctrl.resumed
        assert ctrl.workers["worker-b"].state == "alive", (
            ctrl.workers["worker-b"].state)
        assert held.get("worker-b", 0) > 0, (
            "worker-b held no in-flight work at severance — the reset "
            "message index is not mid-flight")
        assert "worker-a" in ctrl.suspects, (
            "the heartbeat stall never drove suspicion")
        acts_a = {a for _, _, a in ep_a.log}
        assert {"dropped", "duplicated", "partial",
                "stalled"} <= acts_a, acts_a
        assert any(a == "reset" for _, _, a in ep_b.log), ep_b.log
        print(f"chaos-smoke: combined ok — {len(ref)} streams identical"
              f" under drops+partition+kill; requeued="
              f"{ctrl.scheduler.requeued} (kill), resumed="
              f"{ctrl.resumed}, suspects={ctrl.suspects}")

        # -- transient partition alone: resume in place, requeued == 0,
        # and the whole run is bit-reproducible
        runs = []
        for _ in range(2):
            reqs = _make_requests(cfg, args.requests, args.max_new,
                                  args.seed)
            ctrl, held, ep_b = _run_partition(ckpt, reqs, args.seed)
            assert len(ctrl.completed) == args.requests, (
                f"partition lost requests: {sorted(ctrl.completed)}")
            assert _streams(ctrl.completed) == ref, (
                "partition changed token streams")
            assert ctrl.scheduler.requeued == 0, (
                f"transient partition requeued "
                f"{ctrl.scheduler.requeued} requests instead of "
                f"resuming in place")
            assert ctrl.failures == [], ctrl.failures
            assert ctrl.resumed == 1, ctrl.resumed
            assert held.get("worker-b", 0) > 0, held
            runs.append((list(ep_b.log), _streams(ctrl.completed)))
        assert runs[0] == runs[1], (
            "same seed, different run: chaos is not deterministic")
        print(f"chaos-smoke: partition ok — resumed in place holding "
              f"{held['worker-b']} in-flight, requeued=0, two runs "
              f"bit-identical ({len(runs[0][0])} trace entries)")
    print("chaos-smoke: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
