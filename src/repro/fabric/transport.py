"""Fabric wire protocol: typed messages + framed transports.

The controller and its workers speak a small, explicit protocol:

  * ``Hello``          worker -> controller, once after restore: replica
                       identity (name, policy, slots, model config);
  * ``SubmitRequest``  controller -> worker: one request placement;
  * ``TokenChunk``     worker -> controller: newly generated tokens of
                       one request (``done`` carries the finish);
  * ``StatsSnapshot``  worker -> controller: the engine's measured
                       :class:`repro.obs.ReplicaStats` feed — what the
                       router's online cost correction blends instead of
                       reading engine objects directly;
  * ``Heartbeat``      worker -> controller: liveness (a missed-
                       heartbeat window is the failure signal);
  * ``Drain``/``Drained``, ``Shutdown`` — lifecycle control.

Every message crosses an :class:`Endpoint` as a length-prefixed msgpack
frame — including the in-memory pair used by tests and the single-host
controller, so the wire codec is exercised on every path, not just the
multi-process one. ``local_pair()`` gives two connected in-memory
endpoints (deterministic, single-threaded); :class:`SocketEndpoint`
wraps a non-blocking TCP socket for real multi-process runs
(``python -m repro.fabric worker`` connects one back to the
controller's listener).
"""
from __future__ import annotations

import collections
import dataclasses
import socket
import struct
from typing import Any, Deque, Dict, List, Optional, Type

import msgpack

# --------------------------------------------------------------- messages

_MESSAGE_TYPES: Dict[str, Type] = {}


def message(cls):
    """Register a dataclass as a wire message (its class name is the
    type tag)."""
    _MESSAGE_TYPES[cls.__name__] = cls
    return cls


@message
@dataclasses.dataclass(frozen=True)
class Hello:
    name: str
    policy: str
    slots: int
    model_config: Optional[Dict] = None
    cost_correction: str = "static"


@message
@dataclasses.dataclass(frozen=True)
class SubmitRequest:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    priority: int = 0
    tags: List[str] = dataclasses.field(default_factory=list)
    # SamplingParams fields (flat: the wire format has no nested types)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop_ids: List[int] = dataclasses.field(default_factory=list)
    seed: Optional[int] = None


@message
@dataclasses.dataclass(frozen=True)
class TokenChunk:
    rid: int
    tokens: List[int]                  # delta since the last chunk
    done: bool = False
    finish_reason: Optional[str] = None
    truncated: bool = False


@message
@dataclasses.dataclass(frozen=True)
class StatsSnapshot:
    name: str
    stats: Dict                        # ReplicaStats.snapshot()
    slots: int = 0
    completed: int = 0


@message
@dataclasses.dataclass(frozen=True)
class Heartbeat:
    tick: int
    time: float


@message
@dataclasses.dataclass(frozen=True)
class Drain:
    """Finish everything in flight, answer ``Drained``, keep serving."""


@message
@dataclasses.dataclass(frozen=True)
class Drained:
    completed: int = 0


@message
@dataclasses.dataclass(frozen=True)
class Shutdown:
    """Stop the worker loop after the current tick."""


def encode_message(msg: Any) -> bytes:
    name = type(msg).__name__
    if name not in _MESSAGE_TYPES:
        raise TypeError(f"{name} is not a registered fabric message")
    return msgpack.packb({"t": name, "f": dataclasses.asdict(msg)})


def decode_message(data: bytes) -> Any:
    obj = msgpack.unpackb(data)
    cls = _MESSAGE_TYPES.get(obj.get("t"))
    if cls is None:
        raise ValueError(f"unknown fabric message type {obj.get('t')!r}")
    return cls(**obj["f"])


# ---------------------------------------------------------------- framing

_LEN = struct.Struct(">I")
MAX_FRAME = 64 * 1024 * 1024


def pack_frame(payload: bytes) -> bytes:
    if len(payload) > MAX_FRAME:
        raise ValueError(f"frame of {len(payload)} bytes exceeds "
                         f"MAX_FRAME ({MAX_FRAME})")
    return _LEN.pack(len(payload)) + payload


class FrameDecoder:
    """Incremental length-prefixed frame reassembly (feed arbitrary
    byte chunks, iterate complete frames)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[bytes]:
        self._buf.extend(data)
        frames = []
        while len(self._buf) >= _LEN.size:
            (n,) = _LEN.unpack_from(self._buf)
            if n > MAX_FRAME:
                raise ValueError(f"incoming frame of {n} bytes exceeds "
                                 f"MAX_FRAME ({MAX_FRAME})")
            if len(self._buf) < _LEN.size + n:
                break
            frames.append(bytes(self._buf[_LEN.size:_LEN.size + n]))
            del self._buf[:_LEN.size + n]
        return frames


# ------------------------------------------------------------- endpoints

class TransportClosed(RuntimeError):
    """Send on a closed endpoint (the peer is gone)."""


class Endpoint:
    """One side of a bidirectional message channel."""

    def send(self, msg: Any) -> None:
        raise NotImplementedError

    def poll(self) -> List[Any]:
        """Drain every message currently available (non-blocking)."""
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


class LocalEndpoint(Endpoint):
    """In-memory endpoint: deterministic, single-threaded, but every
    message still round-trips through the framed wire encoding so the
    in-process fabric exercises the same codec as the socket one."""

    def __init__(self, inbox: Deque[bytes], outbox: Deque[bytes],
                 state: Dict):
        self._in = inbox
        self._out = outbox
        self._state = state           # shared {'closed': bool}
        self._decoder = FrameDecoder()

    def send(self, msg: Any) -> None:
        if self._state["closed"]:
            raise TransportClosed("endpoint is closed")
        self._out.append(pack_frame(encode_message(msg)))

    def poll(self) -> List[Any]:
        out: List[Any] = []
        while self._in:
            for frame in self._decoder.feed(self._in.popleft()):
                out.append(decode_message(frame))
        return out

    def close(self) -> None:
        self._state["closed"] = True

    @property
    def closed(self) -> bool:
        return self._state["closed"]


def local_pair() -> tuple:
    """Two connected in-memory endpoints (controller side, worker side).
    Closing either side closes both — the fabric's stand-in for a dead
    TCP connection."""
    a_to_b: Deque[bytes] = collections.deque()
    b_to_a: Deque[bytes] = collections.deque()
    state = {"closed": False}
    return (LocalEndpoint(b_to_a, a_to_b, state),
            LocalEndpoint(a_to_b, b_to_a, state))


class SocketEndpoint(Endpoint):
    """Framed messages over a non-blocking TCP socket (the real
    multi-process transport)."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._sock.setblocking(False)
        self._decoder = FrameDecoder()
        self._closed = False

    def send(self, msg: Any) -> None:
        if self._closed:
            raise TransportClosed("socket endpoint is closed")
        data = pack_frame(encode_message(msg))
        try:
            self._sock.setblocking(True)
            self._sock.sendall(data)
        except OSError as e:
            self.close()
            raise TransportClosed(f"peer went away during send: {e}")
        finally:
            if not self._closed:
                self._sock.setblocking(False)

    def poll(self) -> List[Any]:
        out: List[Any] = []
        if self._closed:
            return out
        while True:
            try:
                chunk = self._sock.recv(1 << 16)
            except BlockingIOError:
                break
            except OSError:
                self.close()
                break
            if not chunk:              # orderly EOF: peer closed
                self.close()
                break
            for frame in self._decoder.feed(chunk):
                out.append(decode_message(frame))
        return out

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            try:
                self._sock.close()
            except OSError:
                pass

    @property
    def closed(self) -> bool:
        return self._closed


def connect(host: str, port: int, timeout: float = 30.0) -> SocketEndpoint:
    """Dial the controller's listener (worker side)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(None)
    return SocketEndpoint(sock)


class Listener:
    """Controller-side accept socket: bind an ephemeral port, hand out
    one :class:`SocketEndpoint` per connecting worker."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen()
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout: float = 30.0) -> SocketEndpoint:
        self._sock.settimeout(timeout)
        conn, _ = self._sock.accept()
        return SocketEndpoint(conn)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
