"""Fabric wire protocol: typed messages + framed transports.

The controller and its workers speak a small, explicit protocol:

  * ``Hello``          worker -> controller, once after restore: replica
                       identity (name, policy, slots, model config);
  * ``SubmitRequest``  controller -> worker: one request placement;
  * ``TokenChunk``     worker -> controller: newly generated tokens of
                       one request (``done`` carries the finish);
  * ``StatsSnapshot``  worker -> controller: the engine's measured
                       :class:`repro.obs.ReplicaStats` feed — what the
                       router's online cost correction blends instead of
                       reading engine objects directly;
  * ``Heartbeat``      worker -> controller: liveness (a missed-
                       heartbeat window is the failure signal);
  * ``Drain``/``Drained``, ``Shutdown`` — lifecycle control.

Reconnect-and-resume extends the protocol with three messages:

  * ``Register``       worker -> controller, before it has an engine:
                       dial-in registration (the controller's
                       ``RegisterAck`` hands back the checkpoint
                       directory a fresh host should restore from);
  * ``Resume``         worker -> controller, after a severed
                       connection: per-rid emitted-token counts for
                       every request the worker still holds;
  * ``ResumeAck``      controller -> worker: per-rid *received* counts
                       (the worker rewinds its stream cursor to them,
                       retransmitting anything lost in flight) plus the
                       rids the controller already rerouted (cancel).

``TokenChunk.start`` carries the generation offset of the chunk's
first token so the controller can trim duplicates and ignore stale
retransmissions — token streams stay exact under duplicated or
re-sent frames.

Every message crosses an :class:`Endpoint` as a length-prefixed msgpack
frame — including the in-memory pair used by tests and the single-host
controller, so the wire codec is exercised on every path, not just the
multi-process one. ``local_pair()`` gives two connected in-memory
endpoints (deterministic, single-threaded); :class:`SocketEndpoint`
wraps a non-blocking TCP socket for real multi-process runs
(``python -m repro.fabric worker`` connects one back to the
controller's listener).

Hostile input is a typed failure, never a hang: a corrupt msgpack
payload, an unregistered message type, a field mismatch, or an
oversized frame all raise :class:`ProtocolError` (a ``ValueError``) at
the decode boundary, so a peer feeding garbage can be contained by
closing its endpoint.
"""
from __future__ import annotations

import collections
import dataclasses
import socket
import struct
from typing import Any, Deque, Dict, List, Optional, Type

import msgpack

# ----------------------------------------------------------------- errors

class ProtocolError(ValueError):
    """A peer sent bytes that are not a valid fabric message: corrupt
    msgpack, an unknown message type, mismatched fields, or an
    oversized frame. Typed so the receiving loop can contain the bad
    peer (close its endpoint) instead of crashing or hanging."""


class FrameTooLarge(ProtocolError):
    """A frame header announced a payload beyond ``MAX_FRAME``."""


# --------------------------------------------------------------- messages

_MESSAGE_TYPES: Dict[str, Type] = {}


def message(cls):
    """Register a dataclass as a wire message (its class name is the
    type tag)."""
    _MESSAGE_TYPES[cls.__name__] = cls
    return cls


@message
@dataclasses.dataclass(frozen=True)
class Hello:
    name: str
    policy: str
    slots: int
    model_config: Optional[Dict] = None
    cost_correction: str = "static"
    # a resumable worker keeps its engine (and every in-flight
    # request's state) across a severed connection and will dial back
    # in with a Resume — the controller holds its work through a grace
    # window instead of requeueing on endpoint death
    resumable: bool = False


@message
@dataclasses.dataclass(frozen=True)
class Register:
    """Dial-in registration from a worker that may not have an engine
    yet. ``need_checkpoint`` asks the controller to answer with a
    ``RegisterAck`` naming the checkpoint directory to restore from
    (the fresh-host handoff); the worker follows up with a normal
    ``Hello`` once its engine is serve-ready."""
    name: str
    need_checkpoint: bool = False


@message
@dataclasses.dataclass(frozen=True)
class RegisterAck:
    ckpt_dir: str
    step: Optional[int] = None


@message
@dataclasses.dataclass(frozen=True)
class Resume:
    """A reconnecting worker's ledger: for every request it still
    holds, how many generation tokens its engine has emitted so far
    (streamed or not — the controller answers with what it actually
    received)."""
    name: str
    progress: Dict[int, int] = dataclasses.field(default_factory=dict)


@message
@dataclasses.dataclass(frozen=True)
class ResumeAck:
    """Controller -> worker reconciliation: ``progress`` maps each
    still-wanted rid to the generation-token count the controller has
    received (the worker rewinds its stream cursor there and
    retransmits the rest); ``cancel`` lists rids the controller no
    longer wants from this worker (requeued elsewhere or finished)."""
    progress: Dict[int, int] = dataclasses.field(default_factory=dict)
    cancel: List[int] = dataclasses.field(default_factory=list)


@message
@dataclasses.dataclass(frozen=True)
class SubmitRequest:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    priority: int = 0
    tags: List[str] = dataclasses.field(default_factory=list)
    # SamplingParams fields (flat: the wire format has no nested types)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop_ids: List[int] = dataclasses.field(default_factory=list)
    seed: Optional[int] = None


@message
@dataclasses.dataclass(frozen=True)
class TokenChunk:
    rid: int
    tokens: List[int]                  # delta since the last chunk
    done: bool = False
    finish_reason: Optional[str] = None
    truncated: bool = False
    # generation offset of tokens[0] (0 = the first generated token).
    # Lets the receiver trim duplicated/retransmitted chunks exactly;
    # -1 means "unknown" (pre-resume senders) and is appended blindly.
    start: int = -1


@message
@dataclasses.dataclass(frozen=True)
class StatsSnapshot:
    name: str
    stats: Dict                        # ReplicaStats.snapshot()
    slots: int = 0
    completed: int = 0


@message
@dataclasses.dataclass(frozen=True)
class Heartbeat:
    tick: int
    time: float


@message
@dataclasses.dataclass(frozen=True)
class Drain:
    """Finish everything in flight, answer ``Drained``, keep serving."""


@message
@dataclasses.dataclass(frozen=True)
class Drained:
    completed: int = 0


@message
@dataclasses.dataclass(frozen=True)
class Shutdown:
    """Stop the worker loop after the current tick."""


def encode_message(msg: Any) -> bytes:
    name = type(msg).__name__
    if name not in _MESSAGE_TYPES:
        raise TypeError(f"{name} is not a registered fabric message")
    return msgpack.packb({"t": name, "f": dataclasses.asdict(msg)})


def decode_message(data: bytes) -> Any:
    try:
        # int map keys are legal on this wire (Resume/ResumeAck carry
        # rid -> count ledgers), so strict_map_key must be off
        obj = msgpack.unpackb(data, strict_map_key=False)
    except Exception as e:               # msgpack raises a zoo of types
        raise ProtocolError(f"malformed fabric frame: {e}") from e
    if not isinstance(obj, dict) or "t" not in obj or "f" not in obj:
        raise ProtocolError(
            f"fabric frame is not a typed message envelope: "
            f"{type(obj).__name__}")
    cls = _MESSAGE_TYPES.get(obj.get("t"))
    if cls is None:
        raise ProtocolError(
            f"unknown fabric message type {obj.get('t')!r}")
    fields = obj["f"]
    if not isinstance(fields, dict):
        raise ProtocolError(
            f"{obj['t']} fields are {type(fields).__name__}, not a map")
    try:
        return cls(**fields)
    except TypeError as e:
        raise ProtocolError(f"bad {obj['t']} fields: {e}") from e


# ---------------------------------------------------------------- framing

_LEN = struct.Struct(">I")
MAX_FRAME = 64 * 1024 * 1024


def pack_frame(payload: bytes) -> bytes:
    if len(payload) > MAX_FRAME:
        raise FrameTooLarge(f"frame of {len(payload)} bytes exceeds "
                            f"MAX_FRAME ({MAX_FRAME})")
    return _LEN.pack(len(payload)) + payload


class FrameDecoder:
    """Incremental length-prefixed frame reassembly (feed arbitrary
    byte chunks, iterate complete frames)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[bytes]:
        self._buf.extend(data)
        frames = []
        while len(self._buf) >= _LEN.size:
            (n,) = _LEN.unpack_from(self._buf)
            if n > MAX_FRAME:
                raise FrameTooLarge(
                    f"incoming frame of {n} bytes exceeds "
                    f"MAX_FRAME ({MAX_FRAME})")
            if len(self._buf) < _LEN.size + n:
                break
            frames.append(bytes(self._buf[_LEN.size:_LEN.size + n]))
            del self._buf[:_LEN.size + n]
        return frames

    @property
    def pending_bytes(self) -> int:
        """Bytes buffered toward an incomplete frame (a non-zero value
        at connection close means the stream was truncated mid-frame)."""
        return len(self._buf)


# ------------------------------------------------------------- endpoints

class TransportClosed(RuntimeError):
    """Send on a closed endpoint (the peer is gone)."""


class Endpoint:
    """One side of a bidirectional message channel."""

    def send(self, msg: Any) -> None:
        raise NotImplementedError

    def send_bytes(self, data: bytes) -> None:
        """Ship raw bytes (need not align to frame boundaries). The
        chaos layer uses this to model partial writes and corrupt
        frames; everything else should use ``send``."""
        raise NotImplementedError

    def poll(self) -> List[Any]:
        """Drain every message currently available (non-blocking)."""
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


class LocalEndpoint(Endpoint):
    """In-memory endpoint: deterministic, single-threaded, but every
    message still round-trips through the framed wire encoding so the
    in-process fabric exercises the same codec as the socket one."""

    def __init__(self, inbox: Deque[bytes], outbox: Deque[bytes],
                 state: Dict):
        self._in = inbox
        self._out = outbox
        self._state = state           # shared {'closed': bool}
        self._decoder = FrameDecoder()

    def send(self, msg: Any) -> None:
        if self._state["closed"]:
            raise TransportClosed("endpoint is closed")
        self._out.append(pack_frame(encode_message(msg)))

    def send_bytes(self, data: bytes) -> None:
        if self._state["closed"]:
            raise TransportClosed("endpoint is closed")
        self._out.append(bytes(data))

    def poll(self) -> List[Any]:
        out: List[Any] = []
        while self._in:
            for frame in self._decoder.feed(self._in.popleft()):
                out.append(decode_message(frame))
        return out

    def close(self) -> None:
        self._state["closed"] = True

    @property
    def closed(self) -> bool:
        return self._state["closed"]


def local_pair() -> tuple:
    """Two connected in-memory endpoints (controller side, worker side).
    Closing either side closes both — the fabric's stand-in for a dead
    TCP connection."""
    a_to_b: Deque[bytes] = collections.deque()
    b_to_a: Deque[bytes] = collections.deque()
    state = {"closed": False}
    return (LocalEndpoint(b_to_a, a_to_b, state),
            LocalEndpoint(a_to_b, b_to_a, state))


class SocketEndpoint(Endpoint):
    """Framed messages over a non-blocking TCP socket (the real
    multi-process transport)."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._sock.setblocking(False)
        self._decoder = FrameDecoder()
        self._closed = False

    def send(self, msg: Any) -> None:
        self.send_bytes(pack_frame(encode_message(msg)))

    def send_bytes(self, data: bytes) -> None:
        if self._closed:
            raise TransportClosed("socket endpoint is closed")
        try:
            self._sock.setblocking(True)
            self._sock.sendall(data)
        except OSError as e:
            self.close()
            raise TransportClosed(f"peer went away during send: {e}")
        finally:
            if not self._closed:
                self._sock.setblocking(False)

    def poll(self) -> List[Any]:
        out: List[Any] = []
        if self._closed:
            return out
        while True:
            try:
                chunk = self._sock.recv(1 << 16)
            except BlockingIOError:
                break
            except OSError:
                self.close()
                break
            if not chunk:              # orderly EOF: peer closed
                self.close()
                break
            for frame in self._decoder.feed(chunk):
                out.append(decode_message(frame))
        return out

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            try:
                self._sock.close()
            except OSError:
                pass

    @property
    def closed(self) -> bool:
        return self._closed


def connect(host: str, port: int, timeout: float = 30.0) -> SocketEndpoint:
    """Dial the controller's listener (worker side)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(None)
    return SocketEndpoint(sock)


def backoff_delays(attempts: int, *, base: float = 0.1,
                   factor: float = 2.0, max_delay: float = 5.0,
                   jitter: float = 0.5, seed: int = 0) -> List[float]:
    """The jittered-exponential-backoff schedule ``connect_with_retry``
    sleeps through, as a pure function of the seed — so a fleet of
    workers retrying a restarted controller neither thunders in
    lock-step nor behaves differently run to run."""
    import random
    rng = random.Random(seed)
    out = []
    for k in range(max(attempts, 0)):
        d = min(base * (factor ** k), max_delay)
        out.append(d * (1.0 - jitter * rng.random()))
    return out


def connect_with_retry(host: str, port: int, *, attempts: int = 8,
                       base: float = 0.1, factor: float = 2.0,
                       max_delay: float = 5.0, jitter: float = 0.5,
                       seed: int = 0, timeout: float = 30.0,
                       sleep=None) -> SocketEndpoint:
    """Dial-in with jittered exponential backoff: the deployment-path
    worker keeps trying until the controller's listener answers.
    ``sleep`` is injectable for deterministic tests."""
    import time as _time
    sleep = _time.sleep if sleep is None else sleep
    delays = backoff_delays(attempts, base=base, factor=factor,
                            max_delay=max_delay, jitter=jitter,
                            seed=seed)
    last: Optional[Exception] = None
    for i in range(max(attempts, 1)):
        try:
            return connect(host, port, timeout=timeout)
        except OSError as e:
            last = e
            if i < len(delays):
                sleep(delays[i])
    raise TransportClosed(
        f"could not reach controller at {host}:{port} after "
        f"{attempts} attempts: {last}")


class Listener:
    """Controller-side accept socket: bind an ephemeral port, hand out
    one :class:`SocketEndpoint` per connecting worker."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen()
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout: float = 30.0) -> SocketEndpoint:
        self._sock.settimeout(timeout)
        conn, _ = self._sock.accept()
        return SocketEndpoint(conn)

    def poll_accept(self) -> Optional[SocketEndpoint]:
        """Non-blocking accept: one pending connection or ``None``.
        The controller's tick loop calls this every quantum — dial-in
        workers attach whenever they arrive, no dedicated accept
        thread."""
        self._sock.settimeout(0.0)
        try:
            conn, _ = self._sock.accept()
        except (BlockingIOError, socket.timeout):
            return None
        except OSError:
            return None
        return SocketEndpoint(conn)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
