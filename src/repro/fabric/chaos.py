"""Deterministic fault injection for the serving fabric.

:class:`ChaosEndpoint` wraps any :class:`~repro.fabric.transport.
Endpoint` and applies a declarative :class:`FaultSchedule` to its SEND
path. Faults are a pure function of (seed, message index, clock), so a
chaos run under a :class:`~repro.fabric.controller.ManualClock` is
bit-reproducible: the same schedule produces the same delivery trace,
every time. To fault both directions of a link, wrap both sides with
their own schedules.

The fault vocabulary mirrors how real networks actually fail *above*
TCP:

  * **drop / delay / duplicate** apply (by default) only to telemetry
    — ``Heartbeat`` and ``StatsSnapshot`` — because those messages are
    idempotent by design: the fabric's liveness and cost-correction
    state machines tolerate losing or repeating them. Data-plane
    messages ride a reliable stream; TCP does not drop *individual*
    frames — real data loss manifests as a severed connection, which
    is exactly what ``reset_at_msg`` models (and what the
    reconnect-and-resume machinery recovers from with zero token
    loss). ``TokenChunk`` duplication is additionally safe because
    chunks carry a ``start`` offset the controller dedups on, so
    ``duplicate_every`` applies to every type.
  * **partial writes** (``partial_every``) split a frame's bytes across
    two delivery quanta — the second half arrives on a LATER poll —
    exercising :class:`~repro.fabric.transport.FrameDecoder`
    reassembly on the live path, not just in unit tests.
  * **connection reset** (``reset_at_msg``) severs the link after N
    sends, optionally leaking a truncated half-frame first
    (``reset_truncates``) the way a dying TCP peer does.
  * **heartbeat stalls** (``stall_heartbeats_between``) suppress
    ``Heartbeat`` messages inside a clock window — the shape of a GC
    pause or network partition, which must drive the controller's
    suspect -> dead state machine without any process dying.
  * **scheduled worker death** (``kill_at_tick``) is carried here for
    declarative completeness; the harness turns it into a
    ``failure_hook`` via :func:`fail_at` (the same
    :class:`~repro.runtime.fault_tolerance.WorkerFailure` signal the
    training runtime injects).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.fabric import transport as tp

# message types that are safe to silently lose or reorder: the
# receiving state machines treat them as idempotent samples
TELEMETRY_TYPES = ("Heartbeat", "StatsSnapshot")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Declarative description of what goes wrong on one endpoint's
    send path. All indices count the endpoint's sends (0-based); all
    times are in the injected clock's domain."""
    seed: int = 0
    # telemetry loss: probability of dropping a droppable message
    drop_rate: float = 0.0
    # explicit send indices to drop (droppable types only)
    drop_msgs: Tuple[int, ...] = ()
    # delay: droppable message indices -> seconds of clock delay
    delay_msgs: Tuple[Tuple[int, float], ...] = ()
    # duplicate every Nth send (0 = never); safe for ALL types
    duplicate_every: int = 0
    # split every Nth frame across two delivery quanta (0 = never)
    partial_every: int = 0
    # sever the connection after this many sends (None = never)
    reset_at_msg: Optional[int] = None
    # leak half a frame before the reset (a mid-write peer death)
    reset_truncates: bool = True
    # suppress Heartbeats while t0 <= clock() < t1
    stall_heartbeats_between: Optional[Tuple[float, float]] = None
    # declarative worker death (see fail_at); the endpoint ignores it
    kill_at_tick: Optional[int] = None
    # which message types drop/delay may touch
    droppable: Tuple[str, ...] = TELEMETRY_TYPES

    def __post_init__(self):
        if not (0.0 <= self.drop_rate <= 1.0):
            raise ValueError(f"drop_rate {self.drop_rate} not in [0,1]")
        for knob in ("duplicate_every", "partial_every"):
            if getattr(self, knob) < 0:
                raise ValueError(f"{knob} must be >= 0")


def fail_at(tick: Optional[int]) -> Optional[Callable[[int], None]]:
    """Turn a schedule's ``kill_at_tick`` into the ``failure_hook``
    workers take: raises WorkerFailure at exactly that worker tick —
    the same injectable-death path the training runtime uses."""
    if tick is None:
        return None
    from repro.runtime.fault_tolerance import fail_at_step
    return fail_at_step(tick, reason="chaos: scheduled death")


class ChaosEndpoint(tp.Endpoint):
    """A fault-injecting wrapper over any Endpoint.

    Send-path interception only: ``poll``/``closed`` pass through.
    Deterministic by construction — the RNG is seeded, indices count
    sends, and time comes from the injected clock (pass the fleet's
    ManualClock for bit-reproducible runs).
    """

    def __init__(self, inner: tp.Endpoint, schedule: FaultSchedule,
                 clock: Callable[[], float]):
        self.inner = inner
        self.schedule = schedule
        self.clock = clock
        self._rng = np.random.default_rng(schedule.seed)
        self._sent = 0                 # message send index
        self._frames = 0               # frame emission index
        self._delayed: List[Tuple[float, int, bytes]] = []   # heap
        self._held: List[bytes] = []   # partial-write tails
        self._seq = 0
        self.tripped = False           # reset_at_msg fired
        # delivery trace for determinism assertions:
        # (index, type, action) — 'sent'|'dropped'|'delayed'|
        # 'duplicated'|'partial'|'reset'
        self.log: List[Tuple[int, str, str]] = []

    # ------------------------------------------------------------ faults

    def _droppable(self, tname: str) -> bool:
        return tname in self.schedule.droppable

    def _stalled(self, tname: str) -> bool:
        win = self.schedule.stall_heartbeats_between
        if win is None or tname != "Heartbeat":
            return False
        t = self.clock()
        return win[0] <= t < win[1]

    def _emit(self, data: bytes) -> None:
        """One frame toward the peer, possibly split: the head goes now,
        the tail is held until the NEXT interaction with this endpoint,
        so the receiver's FrameDecoder must reassemble across polls."""
        self._frames += 1
        s = self.schedule
        if self._held:
            # a split frame's tail is in flight: later frames must
            # queue BEHIND it or the byte stream desyncs the decoder
            self._held.append(data)
            return
        if s.partial_every and self._frames % s.partial_every == 0 \
                and len(data) > 4:
            cut = len(data) // 2
            self.inner.send_bytes(data[:cut])
            self._held.append(data[cut:])
            self.log.append((self._sent, "frame", "partial"))
        else:
            self.inner.send_bytes(data)

    def _flush(self) -> None:
        """Release matured delayed messages and held partial tails."""
        if self.inner.closed:
            return
        while self._held:
            self.inner.send_bytes(self._held.pop(0))
        now = self.clock()
        while self._delayed and self._delayed[0][0] <= now:
            _, _, data = heapq.heappop(self._delayed)
            self._emit(data)

    def _reset(self) -> None:
        self.tripped = True
        self.log.append((self._sent, "link", "reset"))
        if self.schedule.reset_truncates and not self.inner.closed:
            # half a frame escapes, then the connection dies: the
            # peer's decoder holds truncated bytes forever
            junk = tp.pack_frame(b"\x00" * 32)[:10]
            try:
                self.inner.send_bytes(junk)
            except tp.TransportClosed:
                pass
        self.inner.close()

    # ---------------------------------------------------------- endpoint

    def send(self, msg: Any) -> None:
        if self.inner.closed and not self.tripped:
            raise tp.TransportClosed("chaos inner endpoint closed")
        self._flush()
        s = self.schedule
        idx = self._sent
        self._sent += 1
        tname = type(msg).__name__
        if s.reset_at_msg is not None and idx >= s.reset_at_msg \
                and not self.tripped:
            self._reset()
            raise tp.TransportClosed(
                f"chaos: connection reset at message {idx}")
        if self.tripped:
            raise tp.TransportClosed("chaos: link was reset")
        if self._stalled(tname):
            self.log.append((idx, tname, "stalled"))
            return
        if self._droppable(tname):
            if idx in s.drop_msgs:
                self.log.append((idx, tname, "dropped"))
                return
            if s.drop_rate and self._rng.random() < s.drop_rate:
                self.log.append((idx, tname, "dropped"))
                return
            delay = dict(s.delay_msgs).get(idx)
            if delay is not None:
                self._seq += 1
                heapq.heappush(
                    self._delayed,
                    (self.clock() + float(delay), self._seq,
                     tp.pack_frame(tp.encode_message(msg))))
                self.log.append((idx, tname, "delayed"))
                return
        data = tp.pack_frame(tp.encode_message(msg))
        self._emit(data)
        self.log.append((idx, tname, "sent"))
        if s.duplicate_every and (idx + 1) % s.duplicate_every == 0:
            self._emit(data)
            self.log.append((idx, tname, "duplicated"))

    def send_bytes(self, data: bytes) -> None:
        self.inner.send_bytes(data)

    def poll(self) -> List[Any]:
        if not self.inner.closed:
            self._flush()
        return self.inner.poll()

    def close(self) -> None:
        self.inner.close()

    @property
    def closed(self) -> bool:
        return self.inner.closed
