"""``python -m repro.fabric smoke`` — the serving-fabric CI contract.

A tiny int4 replica (reduced qwen2, prepared + calibrated) goes through
the full fabric lifecycle on one host, deterministically:

  * **serve-ready checkpoint round trip** — the prepared engine is
    saved (packed int4 storage + scales + calibrated activation scales
    + resolved configs), rebuilt from the checkpoint alone, and the
    restored engine must (a) perform ZERO dynamic weight quants and
    ZERO activation-scale calibrations in its traced decode step —
    restore skipped quantize/pack/calibrate entirely — and (b) serve
    token streams identical to the original engine;
  * **fleet without failures** — a controller with two workers restored
    from the SAME checkpoint serves a workload; every request completes
    with exactly the single-engine reference stream, and the routing
    report shows TRANSPORTED replica stats (ingested StatsSnapshot
    messages, not in-process objects) driving online cost correction;
  * **kill a worker mid-flight** — the same workload, but an injected
    :class:`~repro.runtime.fault_tolerance.WorkerFailure` silences one
    worker while it holds in-flight requests. The controller's
    heartbeat timeout (driven by a ManualClock, so the run is exactly
    reproducible) declares it dead, requeues its in-flight work at the
    front of the fleet queue, and re-admits on the survivor: zero
    requests lost, and every token stream equal to the no-failure run.
"""
from __future__ import annotations

import argparse
import os
import tempfile
from typing import Dict, List

import numpy as np

POLICY = "int4_serving"


def _make_requests(cfg, n: int, max_new: int, seed: int) -> List:
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(3, 12)),
                                        dtype=np.int32),
                    max_new_tokens=max_new,
                    priority=int(rng.integers(0, 3)))
            for rid in range(n)]


def _streams(completed: Dict[int, object]) -> Dict[int, List[int]]:
    return {rid: list(r.tokens) for rid, r in completed.items()}


def _engine_streams(engine, reqs) -> Dict[int, List[int]]:
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    return _streams({r.rid: r for r in reqs})


def _run_fleet(ckpt: str, reqs, *, kill: bool, heartbeat_timeout: float,
               kill_tick: int):
    from repro.fabric.controller import Controller, ManualClock
    from repro.fabric.controller import spawn_local_worker
    from repro.runtime.fault_tolerance import WorkerFailure

    clock = ManualClock()
    ctrl = Controller(heartbeat_timeout=heartbeat_timeout, clock=clock)

    def die_at(tick: int) -> None:
        if kill and tick == kill_tick:
            raise WorkerFailure(f"injected at worker tick {tick}")

    spawn_local_worker(ctrl, ckpt, name="worker-a")
    spawn_local_worker(ctrl, ckpt, name="worker-b", failure_hook=die_at)
    for r in reqs:
        ctrl.submit(r)
    ticks = ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
    return ctrl, ticks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.fabric smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-tick", type=int, default=3,
                    help="worker tick at which the injected failure "
                    "silences worker-b (mid-flight)")
    args = ap.parse_args(argv)

    import jax

    from repro.configs import reduced
    from repro.fabric.checkpoint import build_engine, save_engine_checkpoint
    from repro.models import registry
    from repro.serving.config import EngineConfig
    from repro.serving.engine import ServingEngine
    import dataclasses

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy=POLICY)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    config = EngineConfig(batch_slots=args.slots, cache_len=64,
                          act_calibration="auto",
                          cost_correction="online")
    engine = ServingEngine(cfg, api, params, config=config)

    with tempfile.TemporaryDirectory(prefix="fabric_smoke_") as tmp:
        ckpt = os.path.join(tmp, "ckpt")
        save_engine_checkpoint(engine, ckpt, step=0)

        # -- serve-ready restore: zero quantize/calibrate work, same
        # streams as the engine that was saved
        restored = build_engine(ckpt, api=api)
        wq = restored.weight_quant_trace_count()
        aq = restored.act_quant_trace_count()
        assert wq == 0, f"restored engine quantizes weights ({wq}/step)"
        assert aq == 0, f"restored engine calibrates scales ({aq}/step)"
        assert restored.act_scales == engine.act_scales
        ref = _engine_streams(engine,
                              _make_requests(cfg, args.requests,
                                             args.max_new, args.seed))
        got = _engine_streams(restored,
                              _make_requests(cfg, args.requests,
                                             args.max_new, args.seed))
        assert got == ref, "restored engine diverged from saved engine"
        print(f"fabric-smoke: restore ok — 0 weight quants, 0 act "
              f"calibrations, {len(ref)} identical streams")

        # -- two-worker fleet, no failures: streams == single-engine
        # reference; stats driving routing are transported
        reqs = _make_requests(cfg, args.requests, args.max_new,
                              args.seed)
        ctrl, ticks = _run_fleet(ckpt, reqs, kill=False,
                                 heartbeat_timeout=4.0,
                                 kill_tick=args.kill_tick)
        assert len(ctrl.completed) == args.requests, (
            f"fleet lost requests: {sorted(ctrl.completed)}")
        fleet = _streams(ctrl.completed)
        assert fleet == ref, "fleet streams diverged from single engine"
        rep = ctrl.routing_report()
        assert rep["cost_correction"] == "online", rep["cost_correction"]
        for name, r in rep["replicas"].items():
            assert r["measured"]["transported"], (
                f"{name}: router read in-process stats, not "
                f"transported snapshots")
            assert r["measured"]["tok_per_s"] is not None, (
                f"{name}: no measured throughput crossed the wire")
        routed = ctrl.routing_counters()
        assert all(v > 0 for v in routed.values()), (
            f"a worker got no traffic: {routed}")
        print(f"fabric-smoke: fleet ok — {ticks} ticks, routed={routed},"
              f" online correction over transported stats")

        # -- kill worker-b mid-flight: heartbeat timeout -> requeue ->
        # re-admit on the survivor; zero loss, identical streams
        reqs = _make_requests(cfg, args.requests, args.max_new,
                              args.seed)
        ctrl, ticks = _run_fleet(ckpt, reqs, kill=True,
                                 heartbeat_timeout=4.0,
                                 kill_tick=args.kill_tick)
        assert ctrl.failures == ["worker-b"], ctrl.failures
        assert ctrl.scheduler.requeued > 0, (
            "the dead worker held no in-flight requests — the kill "
            "tick was not mid-flight")
        assert len(ctrl.completed) == args.requests, (
            f"failure lost requests: have {sorted(ctrl.completed)}")
        assert _streams(ctrl.completed) == ref, (
            "failover changed token streams")
        alive = [h.name for h in ctrl.workers.values() if h.alive]
        assert alive == ["worker-a"], alive
        print(f"fabric-smoke: failover ok — worker-b died mid-flight, "
              f"{ctrl.scheduler.requeued} requeued, 0 lost, streams "
              f"identical ({ticks} ticks)")
    print("fabric-smoke: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
