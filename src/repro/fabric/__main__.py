"""``python -m repro.fabric {worker,smoke,chaos}``."""
from __future__ import annotations

import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.fabric {worker,smoke,chaos} "
              "[options]")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "worker":
        from repro.fabric.worker import worker_main
        return worker_main(rest)
    if cmd == "smoke":
        from repro.fabric.smoke import main as smoke_main
        return smoke_main(rest)
    if cmd == "chaos":
        from repro.fabric.chaos_smoke import main as chaos_main
        return chaos_main(rest)
    print(f"unknown repro.fabric command {cmd!r} "
          f"(want worker|smoke|chaos)")
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
