"""Fabric controller: the router promoted to a control plane.

The controller owns the fleet-level waiting line (an
:class:`~repro.serving.scheduler.AdmissionScheduler`), a
:class:`~repro.serving.router.Router` whose replicas are
:class:`RemoteReplica` views over transports, and the failure policy:

  * **placement** — the unchanged Router strategies (plan-aware static
    cost, online measured correction) rank RemoteReplicas exactly like
    in-process ones, because the Replica protocol surface is identical;
    the measured :class:`~repro.obs.ReplicaStats` are *ingested from
    transported StatsSnapshot messages* instead of read off an engine;
  * **streaming** — workers send per-request ``TokenChunk`` deltas; the
    controller accumulates them onto its canonical ``Request`` objects
    (the ones callers submitted), so callers observe finished requests
    exactly as with a local engine;
  * **failure** — a worker is dead when its endpoint closes (process
    exit) or its heartbeats stop for ``heartbeat_timeout`` seconds of
    controller-clock time (silent hang/partition). Death requeues every
    in-flight request of the dead worker at the FRONT of the fleet
    scheduler (``AdmissionScheduler.requeue``) and rebuilds the router
    over the survivors — no request is lost, and because greedy decode
    streams are placement-independent the re-served tokens are
    identical to the no-failure run.

``spawn_local_worker`` runs the worker in-process behind the same wire
codec (a :class:`LocalWorkerDriver` the controller ticks; an injected
:class:`~repro.runtime.fault_tolerance.WorkerFailure` makes it
*silently* dead, exercising the heartbeat-timeout path
deterministically under a :class:`ManualClock`). ``spawn_subprocess_
worker`` is the real multi-process path over TCP.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.fabric import transport as tp
from repro.obs import ReplicaStats
from repro.runtime.fault_tolerance import WorkerFailure
from repro.serving.engine import Request
from repro.serving.scheduler import AdmissionScheduler


class FabricError(RuntimeError):
    """Fleet-level failure the controller cannot route around (e.g. no
    alive workers left with work still queued)."""


class ManualClock:
    """Injectable monotonic clock for deterministic fabric tests: time
    advances only when the test says so."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


class RemoteReplica:
    """The Router's Replica protocol implemented over a transport.

    ``stats`` is a local :class:`ReplicaStats` mirror fed by
    ``ingest()`` from transported snapshots — the router's online cost
    correction blends transported measurements without knowing the
    engine lives elsewhere. ``in_flight`` is the controller's ledger of
    requests placed on this worker that have not finished; it is what
    failure recovery requeues.
    """

    def __init__(self, name: str, policy_name: str,
                 endpoint: tp.Endpoint, *, slots: int,
                 cost: Optional[Dict] = None,
                 cost_correction: str = "static"):
        self.name = name
        self.policy_name = policy_name
        self.endpoint = endpoint
        self.slots = max(int(slots), 1)
        self.cost = dict(cost) if cost else {}
        self.routed = 0
        self.stats = ReplicaStats()
        self.in_flight: Dict[int, Request] = {}
        self.completed: Dict[int, Request] = {}
        self._cost_correction = cost_correction

    @property
    def cost_correction(self) -> str:
        return self._cost_correction

    @property
    def load(self) -> float:
        """Controller-truth occupancy: requests placed but unfinished
        over slots (the transported queue depth lags one tick)."""
        return len(self.in_flight) / self.slots

    def submit(self, req: Request) -> None:
        sp = req.sampling
        self.endpoint.send(tp.SubmitRequest(
            rid=req.rid,
            prompt=[int(t) for t in req.prompt],
            # the effective budget: sampling.max_new_tokens already
            # folded in (the wire carries one budget field)
            max_new_tokens=req.budget,
            priority=req.priority,
            tags=list(req.tags),
            temperature=sp.temperature, top_k=sp.top_k, top_p=sp.top_p,
            stop_ids=list(sp.stop_ids), seed=sp.seed))
        self.in_flight[req.rid] = req

    def has_pending(self) -> bool:
        return bool(self.in_flight)

    def step(self) -> None:
        """Workers drive their own engines; the controller's tick pump
        moves the tokens. Nothing to do here."""

    def metrics(self) -> Dict:
        return {
            "completed": len(self.completed),
            "in_flight": len(self.in_flight),
            "routed": self.routed,
            "replica_stats": self.stats.snapshot(),
        }


class LocalWorkerDriver:
    """Ticks a FabricWorker in-process. A raised
    :class:`WorkerFailure` kills it SILENTLY: the worker stops
    heartbeating but its endpoint stays open — the shape of a hung or
    partitioned node, which only the controller's heartbeat timeout can
    detect (process death closes the socket and is detected
    immediately)."""

    def __init__(self, worker):
        self.worker = worker
        self.dead = False
        self.failure: Optional[WorkerFailure] = None

    def tick(self) -> None:
        if self.dead:
            return
        try:
            self.worker.tick()
        except WorkerFailure as e:
            self.dead = True
            self.failure = e
        except tp.TransportClosed:
            # the controller-side endpoint is gone: the in-process
            # analogue of a worker whose process lost its socket
            self.dead = True


@dataclasses.dataclass
class WorkerHandle:
    name: str
    endpoint: tp.Endpoint
    replica: RemoteReplica
    driver: Optional[LocalWorkerDriver] = None
    process: Optional[object] = None       # subprocess.Popen, if spawned
    last_heartbeat: Optional[float] = None
    alive: bool = True


class Controller:
    """Places requests across fabric workers and survives their death."""

    def __init__(self, *, strategy: str = "plan_aware",
                 cost_correction: Optional[str] = None,
                 online_blend: float = 0.75,
                 heartbeat_timeout: float = 5.0,
                 max_queue: int = 1024,
                 clock: Callable[[], float] = time.monotonic):
        self.strategy = strategy
        self._cost_correction = cost_correction
        self.online_blend = online_blend
        self.heartbeat_timeout = heartbeat_timeout
        self.clock = clock
        self.scheduler = AdmissionScheduler(max_queue=max_queue)
        self.workers: Dict[str, WorkerHandle] = {}
        self.router = None
        self.completed: Dict[int, Request] = {}
        self.requests: Dict[int, Request] = {}
        self.ticks = 0
        self.failures: List[str] = []     # names of workers declared dead

    # ------------------------------------------------------------- fleet

    def _rebuild_router(self) -> None:
        from repro.serving.router import Router
        alive = [h.replica for h in self.workers.values() if h.alive]
        self.router = Router(alive, strategy=self.strategy,
                             cost_correction=self._cost_correction,
                             online_blend=self.online_blend) \
            if alive else None

    def add_worker(self, endpoint: tp.Endpoint, *,
                   driver: Optional[LocalWorkerDriver] = None,
                   process=None, name: Optional[str] = None,
                   hello_timeout: float = 30.0) -> WorkerHandle:
        """Register a worker from its announced identity: wait for its
        ``Hello``, derive the static routing cost from the transported
        model config + policy, add it to the router's fleet."""
        hello, backlog = self._await_hello(endpoint, driver,
                                           hello_timeout)
        wname = name if name is not None else hello.name
        if wname in self.workers:
            n = sum(1 for k in self.workers if k == wname
                    or k.startswith(f"{wname}#"))
            wname = f"{wname}#{n}"
        cost = self._static_cost(hello)
        replica = RemoteReplica(
            wname, hello.policy, endpoint, slots=hello.slots, cost=cost,
            cost_correction=getattr(hello, "cost_correction", "static"))
        handle = WorkerHandle(name=wname, endpoint=endpoint,
                              replica=replica, driver=driver,
                              process=process,
                              last_heartbeat=self.clock())
        self.workers[wname] = handle
        for msg in backlog:               # stats/heartbeats behind Hello
            self._handle_message(handle, msg)
        self._rebuild_router()
        return handle

    def _await_hello(self, endpoint, driver, timeout):
        deadline = time.monotonic() + timeout
        backlog: List = []
        while True:
            if driver is not None:
                driver.tick()             # let an in-process worker talk
            for msg in endpoint.poll():
                if isinstance(msg, tp.Hello):
                    return msg, backlog
                backlog.append(msg)
            if time.monotonic() > deadline:
                raise FabricError("worker never announced (no Hello "
                                  f"within {timeout}s)")
            if driver is None:
                time.sleep(0.01)

    def _static_cost(self, hello: tp.Hello) -> Dict:
        if not hello.model_config:
            return {}
        from repro.core import policy as policy_mod
        from repro.fabric.checkpoint import model_config_from_dict
        from repro.serving.router import replica_cost
        cfg = model_config_from_dict(hello.model_config)
        cfg = dataclasses.replace(cfg, precision_policy=hello.policy)
        return replica_cost(cfg, policy_mod.get_policy(hello.policy))

    # --------------------------------------------------------- submission

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req, now=self.clock())
        self.requests[req.rid] = req

    # --------------------------------------------------------------- tick

    def tick(self) -> int:
        """One control-plane quantum: drive in-process workers, pump
        their messages, detect deaths (requeueing their in-flight
        work), dispatch from the fleet queue. Returns the number of
        inbound messages handled — 0 means the fleet gave us nothing
        this quantum (``run_until_drained`` uses it to pace polling
        of subprocess workers)."""
        for h in self.workers.values():
            if h.alive and h.driver is not None:
                h.driver.tick()
        handled = 0
        for h in self.workers.values():
            if h.alive:
                for msg in h.endpoint.poll():
                    self._handle_message(h, msg)
                    handled += 1
        self._detect_failures()
        self._dispatch()
        self.ticks += 1
        return handled

    def _handle_message(self, h: WorkerHandle, msg) -> None:
        if isinstance(msg, tp.TokenChunk):
            self._on_tokens(h, msg)
        elif isinstance(msg, tp.StatsSnapshot):
            h.replica.stats.ingest(msg.stats)
        elif isinstance(msg, tp.Heartbeat):
            h.last_heartbeat = self.clock()
        # Hello / Drained are lifecycle acks; nothing to update

    def _on_tokens(self, h: WorkerHandle, msg: tp.TokenChunk) -> None:
        req = h.replica.in_flight.get(msg.rid)
        if req is None:
            return                        # stale chunk from a past life
        if req.tokens is None:
            req.tokens = [int(t) for t in req.prompt]
            req.admit_time = self.clock()
        if msg.tokens:
            if req.first_token_time is None:
                req.first_token_time = self.clock()
            req.tokens.extend(int(t) for t in msg.tokens)
        if msg.done:
            req.done = True
            req.finish_reason = msg.finish_reason
            req.truncated = bool(msg.truncated)
            req.finish_time = self.clock()
            del h.replica.in_flight[msg.rid]
            h.replica.completed[msg.rid] = req
            self.completed[msg.rid] = req

    def _detect_failures(self) -> None:
        now = self.clock()
        for h in self.workers.values():
            if not h.alive:
                continue
            silent = (h.last_heartbeat is not None
                      and now - h.last_heartbeat > self.heartbeat_timeout)
            if h.endpoint.closed or silent:
                self._on_worker_death(h)

    def _on_worker_death(self, h: WorkerHandle) -> None:
        """Requeue everything the dead worker owed us, then route around
        it. The requeued requests are RESET to their pre-admission state
        (any partially streamed tokens are discarded) — re-serving from
        scratch on a survivor reproduces the same stream because greedy
        decode is placement-independent."""
        h.alive = False
        self.failures.append(h.name)
        h.endpoint.close()
        for rid in sorted(h.replica.in_flight):
            req = h.replica.in_flight[rid]
            _reset_request(req)
            self.scheduler.requeue(req)
        h.replica.in_flight.clear()
        self._rebuild_router()

    def _dispatch(self) -> None:
        alive = [h.replica for h in self.workers.values() if h.alive]
        if not alive:
            if len(self.scheduler) > 0:
                raise FabricError(
                    f"no alive workers and {len(self.scheduler)} "
                    f"requests queued — the fleet cannot make progress")
            return
        free = sum(max(0, r.slots - len(r.in_flight)) for r in alive)
        if free <= 0 or len(self.scheduler) == 0:
            return
        for req in self.scheduler.select(free, self.clock()):
            rep = self.router.route(req)
            if len(rep.in_flight) >= rep.slots:
                rep = min(alive,
                          key=lambda r: (len(r.in_flight) / r.slots,
                                         r.name))
            rep.routed += 1
            rep.submit(req)

    # ---------------------------------------------------------- execution

    def has_pending(self) -> bool:
        return (len(self.scheduler) > 0
                or any(h.replica.in_flight
                       for h in self.workers.values() if h.alive))

    def run_until_drained(self, max_ticks: int = 10_000,
                          advance: Optional[Callable[[], None]] = None,
                          idle_sleep: float = 0.002) -> int:
        """Drive the fleet until every submitted request completed.
        ``advance`` runs once per tick — under a :class:`ManualClock`
        pass ``lambda: clock.advance(dt)`` so heartbeat windows and
        throughput EWMAs see time moving.

        A tick that handled zero messages while a subprocess worker
        (no local driver) is in the fleet sleeps ``idle_sleep``
        seconds: remote workers make progress on wall clock, not on
        our tick count, and spinning would burn ``max_ticks`` before
        a freshly-restored engine finishes compiling its first step.
        Purely local fleets never sleep — their ticks ARE the work."""
        ticks = 0
        remote = any(h.driver is None for h in self.workers.values())
        while self.has_pending():
            if advance is not None:
                advance()
            handled = self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise FabricError("fleet did not drain "
                                  f"({max_ticks} ticks)")
            if handled == 0 and remote and idle_sleep:
                time.sleep(idle_sleep)
        return ticks

    def shutdown(self) -> None:
        for h in self.workers.values():
            if h.alive and not h.endpoint.closed:
                try:
                    h.endpoint.send(tp.Shutdown())
                except tp.TransportClosed:
                    pass
            if h.driver is not None:
                h.driver.tick()           # let it see the Shutdown
            h.endpoint.close()
            if h.process is not None:
                h.process.wait(timeout=30)

    # ------------------------------------------------------ observability

    def routing_report(self) -> Dict:
        if self.router is None:
            raise FabricError("no alive workers to report on")
        return self.router.routing_report()

    def routing_counters(self) -> Dict[str, int]:
        return {h.name: h.replica.routed for h in self.workers.values()}

    def report(self) -> Dict:
        return {
            "strategy": self.strategy,
            "ticks": self.ticks,
            "failures": list(self.failures),
            "requeued": self.scheduler.requeued,
            "completed": len(self.completed),
            "workers": {
                h.name: {
                    "alive": h.alive,
                    "policy": h.replica.policy_name,
                    **h.replica.metrics(),
                } for h in self.workers.values()
            },
        }


def _reset_request(req: Request) -> None:
    """Back to the pre-admission state ``AdmissionScheduler.requeue``
    expects: only identity (rid/prompt/budget/sampling/priority/tags)
    and ``submit_time`` survive — promotion counts from the original
    submission."""
    req.tokens = None
    req.done = False
    req.error = None
    req.next_input = None
    req.admit_time = None
    req.first_token_time = None
    req.finish_time = None
    req.finish_reason = None
    req.truncated = False
    req.prefill_pos = 0


# ------------------------------------------------------------------ spawn

def spawn_local_worker(controller: Controller, ckpt_dir: str, *,
                       name: str, step: Optional[int] = None,
                       failure_hook: Optional[Callable[[int], None]]
                       = None,
                       config_overrides: Optional[Dict] = None,
                       ) -> WorkerHandle:
    """Restore a worker from a serve-ready checkpoint and attach it
    in-process: same wire codec as a subprocess worker, but ticked by
    the controller and killable via an injected WorkerFailure."""
    from repro.fabric.checkpoint import build_engine
    from repro.fabric.worker import FabricWorker

    ctrl_ep, worker_ep = tp.local_pair()
    engine = build_engine(ckpt_dir, step, clock=controller.clock,
                          config_overrides=config_overrides)
    worker = FabricWorker(name, engine, worker_ep,
                          clock=controller.clock,
                          failure_hook=failure_hook)
    worker.announce()
    driver = LocalWorkerDriver(worker)
    return controller.add_worker(ctrl_ep, driver=driver, name=name)


def spawn_subprocess_worker(controller: Controller, ckpt_dir: str, *,
                            name: str, step: Optional[int] = None,
                            listener: Optional[tp.Listener] = None,
                            timeout: float = 120.0) -> WorkerHandle:
    """The real multi-process path: fork ``python -m repro.fabric
    worker`` against the checkpoint, accept its TCP connection, wait
    for its Hello."""
    import subprocess
    import sys

    own_listener = listener is None
    if own_listener:
        listener = tp.Listener()
    cmd = [sys.executable, "-m", "repro.fabric", "worker",
           "--ckpt", ckpt_dir, "--name", name,
           "--connect", f"{listener.host}:{listener.port}"]
    if step is not None:
        cmd += ["--step", str(step)]
    proc = subprocess.Popen(cmd)
    try:
        endpoint = listener.accept(timeout=timeout)
    finally:
        if own_listener:
            listener.close()
    return controller.add_worker(endpoint, process=proc, name=name,
                                 hello_timeout=timeout)
