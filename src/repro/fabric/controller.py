"""Fabric controller: the router promoted to a control plane.

The controller owns the fleet-level waiting line (an
:class:`~repro.serving.scheduler.AdmissionScheduler`), a
:class:`~repro.serving.router.Router` whose replicas are
:class:`RemoteReplica` views over transports, and the failure policy:

  * **placement** — the unchanged Router strategies (plan-aware static
    cost, online measured correction) rank RemoteReplicas exactly like
    in-process ones, because the Replica protocol surface is identical;
    the measured :class:`~repro.obs.ReplicaStats` are *ingested from
    transported StatsSnapshot messages* instead of read off an engine;
  * **streaming** — workers send per-request ``TokenChunk`` deltas; the
    controller accumulates them onto its canonical ``Request`` objects
    (the ones callers submitted), so callers observe finished requests
    exactly as with a local engine;
  * **failure** — liveness is a two-stage suspect -> dead state
    machine. A worker whose heartbeats go stale for ``suspect_after``
    seconds (or whose endpoint closes, if it announced itself
    resumable) becomes SUSPECT: the controller stops routing new work
    to it but HOLDS its in-flight requests — a GC pause, a transient
    partition, or a reconnecting process should not trigger
    rework. A suspect worker that heartbeats again (or dials back in
    with a ``Resume``) returns to the fleet with its in-flight work
    intact; one that stays silent past ``heartbeat_timeout`` (or
    severed past ``resume_grace``) is DEAD: every in-flight request
    requeues at the FRONT of the fleet scheduler
    (``AdmissionScheduler.requeue``) and the router rebuilds over the
    survivors — no request is lost, and because greedy decode streams
    are placement-independent the re-served tokens are identical to
    the no-failure run. A non-resumable worker's closed endpoint is
    still immediate death (a process exit has nothing to resume);
  * **resume** — a reconnecting worker's ``Resume`` carries per-rid
    emitted-token counts; the controller answers with the counts it
    actually *received* (the worker rewinds its stream cursors there —
    already-streamed tokens are never re-appended, lost ones
    retransmit) plus the rids it rerouted while the worker was gone.
    A transient partition therefore recovers IN PLACE: requeued == 0,
    zero token loss, zero duplicated tokens;
  * **degradation** — when ``shed_factor`` is set, admission sheds
    (``FleetBusy`` with a ``retry_after`` estimate) once the fleet
    queue outgrows the routable capacity, instead of growing the
    waiting line without bound while the fleet is degraded;
    ``drain(deadline)`` bounds how long a drain may take, and
    ``shutdown`` force-kills subprocess workers that ignore it;
  * **containment** — a peer that sends malformed frames (corrupt
    msgpack, unknown message type, oversized frame) raises a typed
    :class:`~repro.fabric.transport.ProtocolError` at the decode
    boundary; the controller records it, closes the endpoint, declares
    the worker dead and requeues its work. Garbage never hangs or
    crashes the control plane.

``spawn_local_worker`` runs the worker in-process behind the same wire
codec (a :class:`LocalWorkerDriver` the controller ticks; an injected
:class:`~repro.runtime.fault_tolerance.WorkerFailure` makes it
*silently* dead, exercising the heartbeat-timeout path
deterministically under a :class:`ManualClock`). ``spawn_subprocess_
worker`` is the real multi-process path over TCP. For deployment the
flow inverts: ``listen()`` opens a :class:`~repro.fabric.transport.
Listener` and dial-in workers (``python -m repro.fabric worker
--connect --register [--resume]``) attach themselves whenever they
arrive — including fresh hosts that take their checkpoint directory
from the controller's ``RegisterAck`` handoff (``checkpoint_dir=``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.fabric import transport as tp
from repro.obs import ReplicaStats
from repro.runtime.fault_tolerance import WorkerFailure
from repro.serving.engine import Request
from repro.serving.scheduler import AdmissionScheduler


class FabricError(RuntimeError):
    """Fleet-level failure the controller cannot route around (e.g. no
    alive workers left with work still queued)."""


class FleetBusy(FabricError):
    """Retriable admission shed: the fleet's routable capacity cannot
    absorb more queued work right now (degraded fleet backpressure).
    ``retry_after`` estimates, in controller-clock seconds, when the
    queue should have drained enough to try again."""

    def __init__(self, msg: str, retry_after: float):
        super().__init__(msg)
        self.retry_after = float(retry_after)


class ManualClock:
    """Injectable monotonic clock for deterministic fabric tests: time
    advances only when the test says so."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


class RemoteReplica:
    """The Router's Replica protocol implemented over a transport.

    ``stats`` is a local :class:`ReplicaStats` mirror fed by
    ``ingest()`` from transported snapshots — the router's online cost
    correction blends transported measurements without knowing the
    engine lives elsewhere. ``in_flight`` is the controller's ledger of
    requests placed on this worker that have not finished; it is what
    failure recovery requeues.
    """

    def __init__(self, name: str, policy_name: str,
                 endpoint: tp.Endpoint, *, slots: int,
                 cost: Optional[Dict] = None,
                 cost_correction: str = "static"):
        self.name = name
        self.policy_name = policy_name
        self.endpoint = endpoint
        self.slots = max(int(slots), 1)
        self.cost = dict(cost) if cost else {}
        self.routed = 0
        self.stats = ReplicaStats()
        self.in_flight: Dict[int, Request] = {}
        self.completed: Dict[int, Request] = {}
        self._cost_correction = cost_correction

    @property
    def cost_correction(self) -> str:
        return self._cost_correction

    @property
    def load(self) -> float:
        """Controller-truth occupancy: requests placed but unfinished
        over slots (the transported queue depth lags one tick)."""
        return len(self.in_flight) / self.slots

    def submit(self, req: Request) -> None:
        sp = req.sampling
        self.endpoint.send(tp.SubmitRequest(
            rid=req.rid,
            prompt=[int(t) for t in req.prompt],
            # the effective budget: sampling.max_new_tokens already
            # folded in (the wire carries one budget field)
            max_new_tokens=req.budget,
            priority=req.priority,
            tags=list(req.tags),
            temperature=sp.temperature, top_k=sp.top_k, top_p=sp.top_p,
            stop_ids=list(sp.stop_ids), seed=sp.seed))
        self.in_flight[req.rid] = req

    def has_pending(self) -> bool:
        return bool(self.in_flight)

    def step(self) -> None:
        """Workers drive their own engines; the controller's tick pump
        moves the tokens. Nothing to do here."""

    def metrics(self) -> Dict:
        return {
            "completed": len(self.completed),
            "in_flight": len(self.in_flight),
            "routed": self.routed,
            "replica_stats": self.stats.snapshot(),
        }


class LocalWorkerDriver:
    """Ticks a FabricWorker in-process. A raised
    :class:`WorkerFailure` kills it SILENTLY: the worker stops
    heartbeating but its endpoint stays open — the shape of a hung or
    partitioned node, which only the controller's heartbeat timeout can
    detect (process death closes the socket and is detected
    immediately)."""

    def __init__(self, worker):
        self.worker = worker
        self.dead = False
        self.failure: Optional[WorkerFailure] = None

    def tick(self) -> None:
        if self.dead:
            return
        try:
            self.worker.tick()
        except WorkerFailure as e:
            self.dead = True
            self.failure = e
        except tp.TransportClosed:
            # the controller-side endpoint is gone: the in-process
            # analogue of a worker whose process lost its socket
            self.dead = True


@dataclasses.dataclass
class WorkerHandle:
    name: str
    endpoint: tp.Endpoint
    replica: RemoteReplica
    driver: Optional[LocalWorkerDriver] = None
    process: Optional[object] = None       # subprocess.Popen, if spawned
    last_heartbeat: Optional[float] = None
    # two-stage liveness: alive -> suspect (stale heartbeats or a
    # severed-but-resumable endpoint; no new work, in-flight HELD) ->
    # dead (grace expired; in-flight requeued). Suspect is reversible.
    state: str = "alive"
    suspect_since: Optional[float] = None
    resumable: bool = False
    drained: bool = False                  # answered the last Drain

    @property
    def alive(self) -> bool:
        """Not declared dead (suspect counts: its work is still held)."""
        return self.state != "dead"

    @property
    def routable(self) -> bool:
        """Eligible for NEW work: alive and not under suspicion."""
        return self.state == "alive"


@dataclasses.dataclass
class PendingEndpoint:
    """An accepted connection that has not identified itself yet (no
    Hello/Resume seen). Dial-in workers and reconnecting workers park
    here until their first protocol message classifies them."""
    endpoint: tp.Endpoint
    since: float
    driver: Optional[LocalWorkerDriver] = None
    process: Optional[object] = None
    backlog: List = dataclasses.field(default_factory=list)


class Controller:
    """Places requests across fabric workers and survives their death."""

    def __init__(self, *, strategy: str = "plan_aware",
                 cost_correction: Optional[str] = None,
                 online_blend: float = 0.75,
                 heartbeat_timeout: float = 5.0,
                 suspect_after: Optional[float] = None,
                 resume_grace: Optional[float] = None,
                 max_queue: int = 1024,
                 shed_factor: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_step: Optional[int] = None,
                 hello_timeout: float = 30.0,
                 shutdown_timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.strategy = strategy
        self._cost_correction = cost_correction
        self.online_blend = online_blend
        self.heartbeat_timeout = heartbeat_timeout
        # suspicion begins at half the death window unless pinned;
        # death timing is unchanged from the one-stage detector
        self.suspect_after = (heartbeat_timeout / 2.0
                              if suspect_after is None else suspect_after)
        if not (0 < self.suspect_after <= heartbeat_timeout):
            raise ValueError(
                f"suspect_after {self.suspect_after} must be in "
                f"(0, heartbeat_timeout={heartbeat_timeout}]")
        # how long a severed resumable worker may stay gone before its
        # work requeues (measured from suspicion, i.e. the severance)
        self.resume_grace = (heartbeat_timeout if resume_grace is None
                             else resume_grace)
        self.clock = clock
        self.scheduler = AdmissionScheduler(max_queue=max_queue)
        # backpressure: shed new submits once the queue exceeds
        # shed_factor x routable slots (None = bounded queue only)
        self.shed_factor = shed_factor
        # checkpoint handoff for dial-in workers that Register without
        # local weights (the fresh-host deployment path)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_step = checkpoint_step
        self.hello_timeout = hello_timeout
        self.shutdown_timeout = shutdown_timeout
        self.workers: Dict[str, WorkerHandle] = {}
        self.listener: Optional[tp.Listener] = None
        self._pending: List[PendingEndpoint] = []
        self.router = None
        self.completed: Dict[int, Request] = {}
        self.requests: Dict[int, Request] = {}
        self.ticks = 0
        self.failures: List[str] = []     # names of workers declared dead
        self.suspects: List[str] = []     # every suspect transition
        self.resumed = 0                  # successful Resume handshakes
        self.shed = 0                     # FleetBusy admission rejections
        self.peer_errors: Dict[str, str] = {}   # name -> ProtocolError

    # ------------------------------------------------------------- fleet

    def _rebuild_router(self) -> None:
        from repro.serving.router import Router
        routable = [h.replica for h in self.workers.values()
                    if h.routable]
        self.router = Router(routable, strategy=self.strategy,
                             cost_correction=self._cost_correction,
                             online_blend=self.online_blend) \
            if routable else None

    def listen(self, host: str = "127.0.0.1",
               port: int = 0) -> tp.Listener:
        """Open the dial-in accept socket: workers that ``connect``
        to (``listener.host``, ``listener.port``) are adopted by the
        tick loop whenever they arrive — worker discovery instead of
        controller-initiated spawn."""
        self.listener = tp.Listener(host, port)
        return self.listener

    def adopt_endpoint(self, endpoint: tp.Endpoint, *,
                       driver: Optional[LocalWorkerDriver] = None,
                       process=None) -> None:
        """Park an unidentified connection; the tick loop classifies
        it by its first protocol message (Hello = new worker, Resume =
        a known worker reconnecting, Register = a fresh host asking
        for the checkpoint handoff)."""
        self._pending.append(PendingEndpoint(
            endpoint=endpoint, since=self.clock(), driver=driver,
            process=process))

    def add_worker(self, endpoint: tp.Endpoint, *,
                   driver: Optional[LocalWorkerDriver] = None,
                   process=None, name: Optional[str] = None,
                   hello_timeout: Optional[float] = None) -> WorkerHandle:
        """Register a worker from its announced identity: wait for its
        ``Hello``, derive the static routing cost from the transported
        model config + policy, add it to the router's fleet."""
        hello, backlog = self._await_hello(
            endpoint, driver,
            self.hello_timeout if hello_timeout is None
            else hello_timeout)
        handle = self._register(endpoint, hello, driver=driver,
                                process=process, name=name)
        for msg in backlog:               # stats/heartbeats behind Hello
            self._handle_message(handle, msg)
        self._rebuild_router()
        return handle

    def _register(self, endpoint: tp.Endpoint, hello: tp.Hello, *,
                  driver=None, process=None,
                  name: Optional[str] = None) -> WorkerHandle:
        wname = name if name is not None else hello.name
        if wname in self.workers:
            n = sum(1 for k in self.workers if k == wname
                    or k.startswith(f"{wname}#"))
            wname = f"{wname}#{n}"
        cost = self._static_cost(hello)
        replica = RemoteReplica(
            wname, hello.policy, endpoint, slots=hello.slots, cost=cost,
            cost_correction=getattr(hello, "cost_correction", "static"))
        handle = WorkerHandle(name=wname, endpoint=endpoint,
                              replica=replica, driver=driver,
                              process=process,
                              last_heartbeat=self.clock(),
                              resumable=bool(getattr(hello, "resumable",
                                                     False)))
        self.workers[wname] = handle
        return handle

    def _answer_register(self, endpoint: tp.Endpoint,
                         msg: tp.Register) -> None:
        """The checkpoint-dir handoff: a fresh host Registers without
        local weights and restores from whatever we hand back."""
        if not msg.need_checkpoint:
            return                        # pure announcement, no reply
        if self.checkpoint_dir is None:
            raise FabricError(
                f"worker {msg.name!r} asked for a checkpoint handoff "
                f"but the controller has no checkpoint_dir configured")
        endpoint.send(tp.RegisterAck(ckpt_dir=self.checkpoint_dir,
                                     step=self.checkpoint_step))

    def _await_hello(self, endpoint, driver, timeout):
        # all deadlines run on the controller's injectable clock —
        # mixing in time.monotonic() here made hello timeouts
        # non-deterministic under a ManualClock
        deadline = self.clock() + timeout
        backlog: List = []
        while True:
            if driver is not None:
                driver.tick()             # let an in-process worker talk
            try:
                msgs = endpoint.poll()
            except tp.ProtocolError as e:
                endpoint.close()
                raise FabricError(
                    f"worker sent garbage before Hello: {e}")
            for msg in msgs:
                if isinstance(msg, tp.Hello):
                    return msg, backlog
                if isinstance(msg, tp.Register):
                    self._answer_register(endpoint, msg)
                    continue
                backlog.append(msg)
            if driver is not None and driver.dead:
                raise FabricError(
                    "worker died before announcing (no Hello)")
            if endpoint.closed:
                raise FabricError(
                    "worker connection closed before Hello")
            if self.clock() > deadline:
                raise FabricError("worker never announced (no Hello "
                                  f"within {timeout}s)")
            if driver is None:
                time.sleep(0.01)

    def _static_cost(self, hello: tp.Hello) -> Dict:
        if not hello.model_config:
            return {}
        from repro.core import policy as policy_mod
        from repro.fabric.checkpoint import model_config_from_dict
        from repro.serving.router import replica_cost
        cfg = model_config_from_dict(hello.model_config)
        cfg = dataclasses.replace(cfg, precision_policy=hello.policy)
        return replica_cost(cfg, policy_mod.get_policy(hello.policy))

    # --------------------------------------------------------- submission

    def submit(self, req: Request) -> None:
        if self.shed_factor is not None:
            capacity = sum(h.replica.slots
                           for h in self.workers.values() if h.routable)
            limit = (max(1, int(self.shed_factor * capacity))
                     if capacity else 0)
            if len(self.scheduler) >= limit:
                self.shed += 1
                raise FleetBusy(
                    f"fleet queue at {len(self.scheduler)} with "
                    f"routable capacity {capacity} (shed_factor="
                    f"{self.shed_factor}); retry later",
                    retry_after=self._retry_after())
        self.scheduler.submit(req, now=self.clock())
        self.requests[req.rid] = req

    def _retry_after(self) -> float:
        """Estimate when the queue should have drained enough to admit:
        pending decode work over the fleet's measured throughput, with
        the heartbeat window as the floor/fallback."""
        tput = sum(h.replica.stats.tok_per_s or 0.0
                   for h in self.workers.values() if h.routable)
        if tput <= 0:
            return self.heartbeat_timeout
        pending = self.scheduler.pending_new_tokens()
        return max(self.heartbeat_timeout / 2.0, pending / tput)

    # --------------------------------------------------------------- tick

    def tick(self) -> int:
        """One control-plane quantum: drive in-process workers, pump
        their messages, detect deaths (requeueing their in-flight
        work), dispatch from the fleet queue. Returns the number of
        inbound messages handled — 0 means the fleet gave us nothing
        this quantum (``run_until_drained`` uses it to pace polling
        of subprocess workers)."""
        self._pump_listener()
        for h in self.workers.values():
            if h.alive and h.driver is not None:
                h.driver.tick()
        handled = 0
        for h in list(self.workers.values()):
            if not h.alive:
                continue
            try:
                msgs = h.endpoint.poll()
            except tp.ProtocolError as e:
                # malformed-frame containment: record, close, declare
                # dead — garbage never hangs the control plane
                self.peer_errors[h.name] = str(e)
                h.endpoint.close()
                self._on_worker_death(h)
                continue
            for msg in msgs:
                self._handle_message(h, msg)
                handled += 1
        handled += self._identify_pending()
        self._detect_failures()
        self._dispatch()
        self.ticks += 1
        return handled

    def _pump_listener(self) -> None:
        if self.listener is None:
            return
        while True:
            ep = self.listener.poll_accept()
            if ep is None:
                return
            self.adopt_endpoint(ep)

    def _identify_pending(self) -> int:
        """Classify parked connections by their first protocol message:
        Hello = new worker joins the fleet, Resume = a known worker
        reconnecting, Register = a fresh host asking for the checkpoint
        handoff (stays pending until its Hello). Garbage or silence past
        ``hello_timeout`` drops the connection."""
        handled = 0
        still: List[PendingEndpoint] = []
        now = self.clock()
        for pe in self._pending:
            if pe.driver is not None:
                pe.driver.tick()
            try:
                msgs = pe.endpoint.poll()
            except tp.ProtocolError as e:
                self.peer_errors[f"<pending@{pe.since:.3f}>"] = str(e)
                pe.endpoint.close()
                continue
            handle: Optional[WorkerHandle] = None
            for msg in msgs:
                handled += 1
                if handle is not None:
                    self._handle_message(handle, msg)
                    continue
                if isinstance(msg, tp.Hello):
                    handle = self._register(pe.endpoint, msg,
                                            driver=pe.driver,
                                            process=pe.process)
                    for m in pe.backlog:
                        self._handle_message(handle, m)
                    pe.backlog.clear()
                    self._rebuild_router()
                elif isinstance(msg, tp.Resume):
                    handle = self._on_resume(pe.endpoint, msg,
                                             driver=pe.driver,
                                             process=pe.process)
                    if handle is None:
                        pe.endpoint.close()
                        break
                elif isinstance(msg, tp.Register):
                    try:
                        self._answer_register(pe.endpoint, msg)
                    except FabricError as e:
                        self.peer_errors[msg.name] = str(e)
                        pe.endpoint.close()
                        break
                else:
                    pe.backlog.append(msg)
            if handle is not None or pe.endpoint.closed:
                continue
            if now - pe.since > self.hello_timeout:
                pe.endpoint.close()       # never identified itself
                continue
            still.append(pe)
        self._pending = still
        return handled

    def _handle_message(self, h: WorkerHandle, msg) -> None:
        if isinstance(msg, tp.TokenChunk):
            self._on_tokens(h, msg)
        elif isinstance(msg, tp.StatsSnapshot):
            h.replica.stats.ingest(msg.stats)
        elif isinstance(msg, tp.Heartbeat):
            h.last_heartbeat = self.clock()
        elif isinstance(msg, tp.Drained):
            h.drained = True
        # Hello is a lifecycle ack; nothing to update

    def _on_tokens(self, h: WorkerHandle, msg: tp.TokenChunk) -> None:
        req = h.replica.in_flight.get(msg.rid)
        if req is None:
            return                        # stale chunk from a past life
        if req.tokens is None:
            req.tokens = [int(t) for t in req.prompt]
            req.admit_time = self.clock()
        toks = msg.tokens or []
        if msg.start >= 0:
            # offset-carrying chunk: dedup against what we already hold.
            # A duplicated frame re-sends tokens we have (skip them); a
            # chunk from the future (gap) means an earlier chunk was
            # lost on a link that will be declared dead — ignore it,
            # Resume or requeue recovers the stream.
            have = len(req.tokens) - len(req.prompt)
            if msg.start > have:
                return
            toks = toks[have - msg.start:]
        if toks:
            if req.first_token_time is None:
                req.first_token_time = self.clock()
            req.tokens.extend(int(t) for t in toks)
        if msg.done:
            req.done = True
            req.finish_reason = msg.finish_reason
            req.truncated = bool(msg.truncated)
            req.finish_time = self.clock()
            del h.replica.in_flight[msg.rid]
            h.replica.completed[msg.rid] = req
            self.completed[msg.rid] = req

    def _detect_failures(self) -> None:
        now = self.clock()
        for h in self.workers.values():
            if not h.alive:
                continue
            if h.endpoint.closed:
                if not h.resumable:
                    # a non-resumable worker's closed endpoint is a
                    # process exit: nothing will ever dial back in
                    self._on_worker_death(h)
                elif h.state == "alive":
                    self._suspect(h, now)
                elif now - h.suspect_since > self.resume_grace:
                    self._on_worker_death(h)
                continue
            if h.last_heartbeat is None:
                continue
            age = now - h.last_heartbeat
            if age > self.heartbeat_timeout:
                self._on_worker_death(h)
            elif age > self.suspect_after:
                if h.state == "alive":
                    self._suspect(h, now)
            elif h.state == "suspect":
                # heartbeats recovered before the grace expired: the
                # pause/partition was transient, resume routing
                h.state = "alive"
                h.suspect_since = None
                self._rebuild_router()

    def _suspect(self, h: WorkerHandle, now: float) -> None:
        h.state = "suspect"
        h.suspect_since = now
        self.suspects.append(h.name)
        self._rebuild_router()            # stop routing NEW work to it

    def _on_worker_death(self, h: WorkerHandle) -> None:
        """Requeue everything the dead worker owed us, then route around
        it. The requeued requests are RESET to their pre-admission state
        (any partially streamed tokens are discarded) — re-serving from
        scratch on a survivor reproduces the same stream because greedy
        decode is placement-independent."""
        h.state = "dead"
        h.suspect_since = None
        self.failures.append(h.name)
        h.endpoint.close()
        for rid in sorted(h.replica.in_flight):
            req = h.replica.in_flight[rid]
            _reset_request(req)
            self.scheduler.requeue(req)
        h.replica.in_flight.clear()
        self._rebuild_router()

    def _on_resume(self, endpoint: tp.Endpoint, msg: tp.Resume, *,
                   driver: Optional[LocalWorkerDriver] = None,
                   process=None) -> Optional[WorkerHandle]:
        """Reconcile a reconnecting worker's progress ledger with ours.

        The worker reports how many tokens it has GENERATED per rid; we
        answer with how many we RECEIVED (it rewinds its stream cursors
        there — lost chunks retransmit, delivered ones never repeat) and
        which rids to cancel (requeued elsewhere, finished, or unknown).
        A suspect worker resumes IN PLACE: in-flight work intact,
        requeued == 0. A worker that comes back after being declared
        dead rejoins empty-handed — its work already requeued."""
        h = self.workers.get(msg.name)
        if h is None:
            return None                   # never knew this name
        was_dead = h.state == "dead"
        progress: Dict[int, int] = {}
        cancel: List[int] = []
        if was_dead:
            # everything it thinks it owns was already requeued or
            # finished elsewhere; it rejoins with a clean slate
            cancel = sorted(int(r) for r in msg.progress)
        else:
            for rid, req in list(h.replica.in_flight.items()):
                if rid not in msg.progress:
                    # the worker lost this request entirely (e.g. it
                    # restarted): re-serve it from scratch elsewhere
                    _reset_request(req)
                    self.scheduler.requeue(req)
                    del h.replica.in_flight[rid]
                    continue
                have = (0 if req.tokens is None
                        else len(req.tokens) - len(req.prompt))
                progress[int(rid)] = int(have)
            for rid in msg.progress:
                if int(rid) not in h.replica.in_flight:
                    cancel.append(int(rid))
        # adopt the fresh endpoint on both views of the worker
        old = h.endpoint
        h.endpoint = endpoint
        h.replica.endpoint = endpoint
        if old is not endpoint:
            old.close()
        if driver is not None:
            h.driver = driver
        if process is not None:
            h.process = process
        h.state = "alive"
        h.suspect_since = None
        h.last_heartbeat = self.clock()
        endpoint.send(tp.ResumeAck(progress=progress,
                                   cancel=sorted(cancel)))
        self.resumed += 1
        self._rebuild_router()
        return h

    def _dispatch(self) -> None:
        if not any(h.alive for h in self.workers.values()):
            if len(self.scheduler) > 0:
                raise FabricError(
                    f"no alive workers and {len(self.scheduler)} "
                    f"requests queued — the fleet cannot make progress")
            return
        # only fully-alive workers take NEW work; suspects hold theirs
        routable = [h.replica for h in self.workers.values()
                    if h.routable]
        if not routable:
            return                        # whole fleet under suspicion
        free = sum(max(0, r.slots - len(r.in_flight)) for r in routable)
        if free <= 0 or len(self.scheduler) == 0:
            return
        for req in self.scheduler.select(free, self.clock()):
            rep = self.router.route(req)
            if len(rep.in_flight) >= rep.slots:
                rep = min(routable,
                          key=lambda r: (len(r.in_flight) / r.slots,
                                         r.name))
            rep.routed += 1
            rep.submit(req)

    # ---------------------------------------------------------- execution

    def has_pending(self) -> bool:
        return (len(self.scheduler) > 0
                or any(h.replica.in_flight
                       for h in self.workers.values() if h.alive))

    def run_until_drained(self, max_ticks: int = 10_000,
                          advance: Optional[Callable[[], None]] = None,
                          idle_sleep: float = 0.002) -> int:
        """Drive the fleet until every submitted request completed.
        ``advance`` runs once per tick — under a :class:`ManualClock`
        pass ``lambda: clock.advance(dt)`` so heartbeat windows and
        throughput EWMAs see time moving.

        A tick that handled zero messages while a subprocess worker
        (no local driver) is in the fleet sleeps ``idle_sleep``
        seconds: remote workers make progress on wall clock, not on
        our tick count, and spinning would burn ``max_ticks`` before
        a freshly-restored engine finishes compiling its first step.
        Purely local fleets never sleep — their ticks ARE the work."""
        ticks = 0
        remote = any(h.driver is None for h in self.workers.values())
        while self.has_pending():
            if advance is not None:
                advance()
            handled = self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise FabricError("fleet did not drain "
                                  f"({max_ticks} ticks)")
            if handled == 0 and remote and idle_sleep:
                time.sleep(idle_sleep)
        return ticks

    def drain(self, deadline: float,
              advance: Optional[Callable[[], None]] = None,
              idle_sleep: float = 0.002) -> bool:
        """Ask every live worker to finish in-flight work and stop
        admitting, then tick until all have answered ``Drained`` or
        ``deadline`` controller-clock seconds elapse. Returns True if
        the whole fleet drained in time; False means the caller should
        escalate to ``shutdown()`` (which force-kills stragglers)."""
        for h in self.workers.values():
            h.drained = False
        limit = self.clock() + deadline
        remote = any(h.driver is None for h in self.workers.values())
        targets: List[WorkerHandle] = []
        asked = False
        while True:
            if not asked and len(self.scheduler) == 0:
                # nothing left to hand out: NOW tell workers to finish
                # what they hold and stop; asking earlier would let an
                # idle worker answer Drained before its share of the
                # queue ever reached it
                for h in self.workers.values():
                    if h.alive and not h.endpoint.closed:
                        try:
                            h.endpoint.send(tp.Drain())
                            targets.append(h)
                        except tp.TransportClosed:
                            pass
                asked = True
            if asked and all(h.drained or not h.alive
                             for h in targets):
                return True
            if self.clock() > limit:
                return False
            if advance is not None:
                advance()
            if self.tick() == 0 and remote and idle_sleep:
                time.sleep(idle_sleep)

    def shutdown(self) -> None:
        for h in self.workers.values():
            if h.alive and not h.endpoint.closed:
                try:
                    h.endpoint.send(tp.Shutdown())
                except tp.TransportClosed:
                    pass
            if h.driver is not None:
                h.driver.tick()           # let it see the Shutdown
            h.endpoint.close()
            if h.process is not None:
                try:
                    h.process.wait(timeout=self.shutdown_timeout)
                except Exception:
                    # a worker that ignores Shutdown past the deadline
                    # is force-killed: drain deadlines stay deadlines
                    h.process.kill()
                    h.process.wait(timeout=5)
        for pe in self._pending:
            pe.endpoint.close()
        self._pending.clear()
        if self.listener is not None:
            self.listener.close()
            self.listener = None

    # ------------------------------------------------------ observability

    def routing_report(self) -> Dict:
        if self.router is None:
            raise FabricError("no alive workers to report on")
        return self.router.routing_report()

    def routing_counters(self) -> Dict[str, int]:
        return {h.name: h.replica.routed for h in self.workers.values()}

    def report(self) -> Dict:
        return {
            "strategy": self.strategy,
            "ticks": self.ticks,
            "failures": list(self.failures),
            "suspects": list(self.suspects),
            "resumed": self.resumed,
            "shed": self.shed,
            "peer_errors": dict(self.peer_errors),
            "requeued": self.scheduler.requeued,
            "completed": len(self.completed),
            "workers": {
                h.name: {
                    "alive": h.alive,
                    "state": h.state,
                    "policy": h.replica.policy_name,
                    **h.replica.metrics(),
                } for h in self.workers.values()
            },
        }


def _reset_request(req: Request) -> None:
    """Back to the pre-admission state ``AdmissionScheduler.requeue``
    expects: only identity (rid/prompt/budget/sampling/priority/tags)
    and ``submit_time`` survive — promotion counts from the original
    submission."""
    req.tokens = None
    req.done = False
    req.error = None
    req.next_input = None
    req.admit_time = None
    req.first_token_time = None
    req.finish_time = None
    req.finish_reason = None
    req.truncated = False
    req.prefill_pos = 0


# ------------------------------------------------------------------ spawn

def spawn_local_worker(controller: Controller, ckpt_dir: str, *,
                       name: str, step: Optional[int] = None,
                       failure_hook: Optional[Callable[[int], None]]
                       = None,
                       config_overrides: Optional[Dict] = None,
                       resumable: bool = False) -> WorkerHandle:
    """Restore a worker from a serve-ready checkpoint and attach it
    in-process: same wire codec as a subprocess worker, but ticked by
    the controller and killable via an injected WorkerFailure. With
    ``resumable=True`` the worker survives a severed endpoint and can
    be re-attached via ``reattach_local_worker``."""
    from repro.fabric.checkpoint import build_engine
    from repro.fabric.worker import FabricWorker

    ctrl_ep, worker_ep = tp.local_pair()
    engine = build_engine(ckpt_dir, step, clock=controller.clock,
                          config_overrides=config_overrides)
    worker = FabricWorker(name, engine, worker_ep,
                          clock=controller.clock,
                          failure_hook=failure_hook,
                          resumable=resumable)
    worker.announce()
    driver = LocalWorkerDriver(worker)
    return controller.add_worker(ctrl_ep, driver=driver, name=name)


def reattach_local_worker(controller: Controller, worker) -> None:
    """Heal a severed in-process worker: make a fresh local pair, have
    the worker open the Resume handshake on it, and park the controller
    side for the tick loop to reconcile. The in-memory analogue of a
    subprocess worker redialing the controller's listener."""
    ctrl_ep, worker_ep = tp.local_pair()
    worker.reconnect(worker_ep)
    driver = LocalWorkerDriver(worker)
    controller.adopt_endpoint(ctrl_ep, driver=driver)


def spawn_subprocess_worker(controller: Controller,
                            ckpt_dir: Optional[str] = None, *,
                            name: str, step: Optional[int] = None,
                            listener: Optional[tp.Listener] = None,
                            resumable: bool = False,
                            register: bool = False,
                            timeout: float = 120.0) -> WorkerHandle:
    """The real multi-process path: fork ``python -m repro.fabric
    worker`` against the checkpoint, accept its TCP connection, wait
    for its Hello.

    ``register=True`` is the fresh-host path: fork WITHOUT ``--ckpt``
    and let the worker take its checkpoint directory from the
    controller's ``RegisterAck`` handoff (requires the controller's
    ``checkpoint_dir``). ``resumable=True`` starts the worker with
    ``--resume`` so a dropped connection redials the listener —
    pass the controller's persistent ``listen()`` socket in that case
    (an ephemeral one closes after the first accept and the redial
    would find nobody home)."""
    import subprocess
    import sys

    if ckpt_dir is None and not register:
        raise ValueError("ckpt_dir is required unless register=True")
    own_listener = listener is None
    if own_listener:
        listener = controller.listener or tp.Listener()
        own_listener = listener is not controller.listener
    cmd = [sys.executable, "-m", "repro.fabric", "worker",
           "--name", name,
           "--connect", f"{listener.host}:{listener.port}"]
    if ckpt_dir is not None:
        cmd += ["--ckpt", ckpt_dir]
    if register:
        cmd += ["--register"]
    if resumable:
        cmd += ["--resume"]
    if step is not None:
        cmd += ["--step", str(step)]
    proc = subprocess.Popen(cmd)
    try:
        endpoint = listener.accept(timeout=timeout)
    finally:
        if own_listener:
            listener.close()
    return controller.add_worker(endpoint, process=proc, name=name,
                                 hello_timeout=timeout)
