"""Multi-host serving fabric: checkpoint-restored workers behind a
router-driven controller.

The serving stack so far lived in one process: ``Router`` held its
``ServingEngine`` replicas as Python objects. The fabric promotes
replicas to *addressable workers*:

  * ``fabric.checkpoint`` — serve-ready checkpoints: prepared
    (quantized/packed/calibrated) engine state that restores bit-exactly
    with zero re-quantization work;
  * ``fabric.transport`` — typed messages over framed msgpack
    endpoints (deterministic in-memory pair / TCP sockets);
  * ``fabric.worker`` — the engine tick loop behind an endpoint;
  * ``fabric.controller`` — fleet admission + routing + failure
    recovery (suspect -> dead liveness, reconnect-and-resume
    reconciliation, requeue, admission shed, drain deadlines);
  * ``fabric.chaos`` — seeded, clock-driven fault injection
    (:class:`FaultSchedule` + :class:`ChaosEndpoint`): every failure
    mode above is a deterministic, replayable test input.

``python -m repro.fabric smoke`` runs the kill-a-worker-mid-flight CI
contract; ``python -m repro.fabric chaos`` runs the seeded
drops+partition+kill contract (zero loss, resume in place);
``python -m repro.fabric worker`` is the subprocess entry.

Multi-host deployment walkthrough
---------------------------------

The controller is the only fixed address; workers dial IN (discovery,
not spawn)::

    # host A — the control plane
    ctrl = Controller(checkpoint_dir="/shared/ckpt",   # handoff source
                      shed_factor=4.0)                 # backpressure
    lst = ctrl.listen("0.0.0.0", 7000)
    ...
    while True:                       # serve loop
        ctrl.tick()                   # accepts + classifies dial-ins

    # host B..N — workers, started any time, in any order
    #   fresh host, no local weights: Register -> RegisterAck hands it
    #   the checkpoint directory, then it announces with Hello
    python -m repro.fabric worker --register --resume \
        --name worker-b --connect hostA:7000
    #   host with a local checkpoint copy:
    python -m repro.fabric worker --ckpt /local/ckpt --resume \
        --name worker-c --connect hostA:7000

``--resume`` makes a dropped connection redial (jittered exponential
backoff, seeded) and reconcile via ``Resume``/``ResumeAck`` — the
engine and its in-flight requests never reset, already-streamed tokens
are never re-sent. Without it a disconnect is a clean exit and the
controller requeues. ``ctrl.drain(deadline)`` bounds shutdown;
``ctrl.shutdown()`` force-kills workers that ignore it.
"""
from repro.fabric.chaos import ChaosEndpoint, FaultSchedule, fail_at
from repro.fabric.checkpoint import (build_engine, load_engine_checkpoint,
                                     save_engine_checkpoint)
from repro.fabric.controller import (Controller, FabricError, FleetBusy,
                                     LocalWorkerDriver, ManualClock,
                                     RemoteReplica, WorkerHandle,
                                     reattach_local_worker,
                                     spawn_local_worker,
                                     spawn_subprocess_worker)
from repro.fabric.transport import (Drain, Drained, Endpoint,
                                    FrameDecoder, FrameTooLarge,
                                    Heartbeat, Hello, Listener,
                                    LocalEndpoint, ProtocolError,
                                    Register, RegisterAck, Resume,
                                    ResumeAck, Shutdown,
                                    SocketEndpoint, StatsSnapshot,
                                    SubmitRequest, TokenChunk,
                                    TransportClosed, backoff_delays,
                                    connect, connect_with_retry,
                                    decode_message, encode_message,
                                    local_pair, pack_frame)
from repro.fabric.worker import FabricWorker, worker_main

__all__ = [
    "ChaosEndpoint", "Controller", "Drain", "Drained", "Endpoint",
    "FabricError", "FabricWorker", "FaultSchedule", "FleetBusy",
    "FrameDecoder", "FrameTooLarge", "Heartbeat", "Hello", "Listener",
    "LocalEndpoint", "LocalWorkerDriver", "ManualClock",
    "ProtocolError", "Register", "RegisterAck", "RemoteReplica",
    "Resume", "ResumeAck", "Shutdown", "SocketEndpoint",
    "StatsSnapshot", "SubmitRequest", "TokenChunk", "TransportClosed",
    "WorkerHandle", "backoff_delays", "build_engine", "connect",
    "connect_with_retry", "decode_message", "encode_message",
    "fail_at", "load_engine_checkpoint", "local_pair", "pack_frame",
    "reattach_local_worker", "save_engine_checkpoint",
    "spawn_local_worker", "spawn_subprocess_worker", "worker_main",
]
