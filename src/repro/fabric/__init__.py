"""Multi-host serving fabric: checkpoint-restored workers behind a
router-driven controller.

The serving stack so far lived in one process: ``Router`` held its
``ServingEngine`` replicas as Python objects. The fabric promotes
replicas to *addressable workers*:

  * ``fabric.checkpoint`` — serve-ready checkpoints: prepared
    (quantized/packed/calibrated) engine state that restores bit-exactly
    with zero re-quantization work;
  * ``fabric.transport`` — typed messages over framed msgpack
    endpoints (deterministic in-memory pair / TCP sockets);
  * ``fabric.worker`` — the engine tick loop behind an endpoint;
  * ``fabric.controller`` — fleet admission + routing + failure
    recovery (heartbeat timeouts, requeue, re-admission).

``python -m repro.fabric smoke`` runs the kill-a-worker-mid-flight CI
contract; ``python -m repro.fabric worker`` is the subprocess entry.
"""
from repro.fabric.checkpoint import (build_engine, load_engine_checkpoint,
                                     save_engine_checkpoint)
from repro.fabric.controller import (Controller, FabricError,
                                     LocalWorkerDriver, ManualClock,
                                     RemoteReplica, WorkerHandle,
                                     spawn_local_worker,
                                     spawn_subprocess_worker)
from repro.fabric.transport import (Drain, Drained, Endpoint,
                                    FrameDecoder, Heartbeat, Hello,
                                    Listener, LocalEndpoint, Shutdown,
                                    SocketEndpoint, StatsSnapshot,
                                    SubmitRequest, TokenChunk,
                                    TransportClosed, connect,
                                    decode_message, encode_message,
                                    local_pair, pack_frame)
from repro.fabric.worker import FabricWorker, worker_main

__all__ = [
    "Controller", "Drain", "Drained", "Endpoint", "FabricError",
    "FabricWorker", "FrameDecoder", "Heartbeat", "Hello", "Listener",
    "LocalEndpoint", "LocalWorkerDriver", "ManualClock",
    "RemoteReplica", "Shutdown", "SocketEndpoint", "StatsSnapshot",
    "SubmitRequest", "TokenChunk", "TransportClosed", "WorkerHandle",
    "build_engine", "connect", "decode_message", "encode_message",
    "load_engine_checkpoint", "local_pair", "pack_frame",
    "save_engine_checkpoint", "spawn_local_worker",
    "spawn_subprocess_worker", "worker_main",
]
