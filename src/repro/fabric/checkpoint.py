"""Prepared-engine checkpointing: serve-ready state on disk.

``save_engine_checkpoint`` persists everything a fabric worker needs to
come back as the SAME replica: the engine's prepared param tree (packed
int8/int4 storage, per-channel scales, calibrated activation scales —
the :class:`repro.quant.prepare.PreparedWeight` containers, bit-exact
via ``repro.checkpoint``'s self-describing manifest) plus the resolved
``ModelConfig`` and ``EngineConfig`` in the checkpoint metadata.

``build_engine`` is the restore path: it reconstructs a
``ServingEngine`` from the checkpoint alone — no raw fp32 weights, no
re-quantization, no calibration pass. Two properties make that cheap:

  * ``prepare_params`` is idempotent — prepared containers pass
    through untouched, so the restored engine's construction-time
    prepare is a pure tree walk (``weight_quant_trace_count() == 0``
    exactly as for the original engine);
  * the saved activation scales feed back through
    ``EngineConfig(act_calibration=<dict>)``, whose dict path skips the
    calibration forwards entirely.

This is the cold-start story the benchmark's ``cold_start`` section
measures: engine-from-checkpoint skips init + quantize/pack +
calibrate, and the checkpoint itself is the *quantized* footprint
(int4 ≈ 1/8 of fp32 projection bytes on disk, not just in memory).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs.base import ModelConfig, MoESpec
from repro.serving.config import EngineConfig

FABRIC_KEY = "fabric"
FORMAT_VERSION = 1


# ------------------------------------------------------- config round trip
#
# msgpack has no tuples — everything tuple-typed (rec_pattern, stop-id
# lists) comes back as a list, so the rebuild coerces per-field against
# the dataclass schema instead of trusting the wire types.

def model_config_to_dict(cfg: ModelConfig) -> Dict:
    return dataclasses.asdict(cfg)


def model_config_from_dict(d: Dict) -> ModelConfig:
    d = dict(d)
    if d.get("moe") is not None:
        d["moe"] = MoESpec(**d["moe"])
    if d.get("rec_pattern") is not None:
        d["rec_pattern"] = tuple(d["rec_pattern"])
    known = {f.name for f in dataclasses.fields(ModelConfig)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(
            f"checkpoint model config carries unknown fields "
            f"{sorted(unknown)} (schema drift — re-save the checkpoint)")
    return ModelConfig(**d)


def engine_config_to_dict(config: EngineConfig) -> Dict:
    d = dataclasses.asdict(config)
    # the calibration INPUT is not serve-ready state: a dict is saved
    # separately as act_scales, and 'auto' must not re-trigger a
    # calibration pass on restore — the restore path reinjects the
    # resolved scales
    d.pop("act_calibration", None)
    return d


def engine_config_from_dict(d: Dict,
                            act_scales: Optional[Dict]) -> EngineConfig:
    d = dict(d)
    d.pop("act_calibration", None)
    known = {f.name for f in dataclasses.fields(EngineConfig)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(
            f"checkpoint engine config carries unknown fields "
            f"{sorted(unknown)} (schema drift — re-save the checkpoint)")
    return EngineConfig(act_calibration=act_scales, **d)


# ------------------------------------------------------------ save/restore

def save_engine_checkpoint(engine, directory: str, step: int = 0) -> str:
    """Persist a constructed ``ServingEngine`` as a serve-ready
    checkpoint: prepared params as the array payload, resolved configs
    and activation scales in the manifest metadata."""
    scales = None
    if engine.act_scales is not None:
        scales = {k: float(v) for k, v in engine.act_scales.items()}
    meta = {
        FABRIC_KEY: {
            "version": FORMAT_VERSION,
            "model_config": model_config_to_dict(engine.cfg),
            "engine_config": engine_config_to_dict(engine.config),
            "act_scales": scales,
            "policy": engine.cfg.precision_policy,
            "prepared": bool(engine.prepared),
        }
    }
    return save_checkpoint(directory, step, engine.params, metadata=meta)


def load_engine_checkpoint(directory: str, step: Optional[int] = None,
                           ) -> Tuple[ModelConfig, EngineConfig, Any,
                                      Optional[Dict], Dict]:
    """Restore ``(model_cfg, engine_cfg, params, act_scales, meta)``
    from a serve-ready checkpoint.

    The param tree comes back self-describing (no ``like`` template —
    the only restore mode that round-trips packed int4 storage
    bit-exactly) with per-leaf checksums verified."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            from repro.checkpoint import CheckpointNotFound
            raise CheckpointNotFound(
                f"no checkpoints under {directory!r}")
    params, meta = restore_checkpoint(directory, step)
    fab = meta.get(FABRIC_KEY)
    if fab is None:
        raise ValueError(
            f"checkpoint at {directory!r} step {step} is not a fabric "
            f"engine checkpoint (no {FABRIC_KEY!r} metadata) — it "
            f"cannot rebuild a ServingEngine; restore it with "
            f"repro.checkpoint.restore_checkpoint instead")
    cfg = model_config_from_dict(fab["model_config"])
    act_scales = fab.get("act_scales")
    config = engine_config_from_dict(fab["engine_config"], act_scales)
    return cfg, config, params, act_scales, fab


def build_engine(directory: str, step: Optional[int] = None, *,
                 api=None, scheduler=None, clock=None,
                 config_overrides: Optional[Dict] = None):
    """Reconstruct a serve-ready ``ServingEngine`` from a checkpoint.

    The prepared tree passes straight through the engine's
    construction-time prepare (idempotent), and the saved activation
    scales ride in as the dict ``act_calibration`` — so the rebuilt
    engine performs zero weight quantizations and zero calibration
    forwards, and serves token streams identical to the engine that was
    saved. ``config_overrides`` patches EngineConfig fields that are
    deployment-local rather than replica identity (e.g. ``trace``,
    ``cost_correction``)."""
    import time

    from repro.models import registry
    from repro.serving.engine import ServingEngine

    cfg, config, params, _, _ = load_engine_checkpoint(directory, step)
    if config_overrides:
        config = dataclasses.replace(config, **config_overrides)
    if api is None:
        api = registry.build(cfg)
    return ServingEngine(cfg, api, params, config=config,
                         scheduler=scheduler,
                         clock=clock if clock is not None
                         else time.monotonic)
