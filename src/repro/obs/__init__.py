"""Serving telemetry: span tracing, typed metrics, measured replica stats.

The measurement substrate the serving stack (and every fleet-level
ROADMAP item) consumes, mirroring the paper's own method — replace
worst-case assumptions with *observed* distributions. Three small
pieces, all dependency-free (numpy only) and clock-injectable so tests
are deterministic:

* :mod:`repro.obs.trace` — :class:`Tracer`: explicit-clock spans
  (request lifecycle, per-tick engine phases, JAX compile events)
  exported as Chrome trace-event JSON loadable in Perfetto
  (https://ui.perfetto.dev). ``traced_jit`` wraps a jitted callable so
  each compilation surfaces as a ``compile`` span.
* :mod:`repro.obs.registry` — :class:`MetricsRegistry`: typed
  counters/gauges/histograms plus rolling-window gauges sampled per
  engine tick. The registry's counters back the engine's
  ``metrics()["counters"]`` dict bit-compatibly through
  :class:`CountersView`. This module also owns the CANONICAL
  percentile-block schema (``PERCENTILES`` + ``percentile_block``)
  that ``repro.serving.metrics`` re-exports.
* :mod:`repro.obs.stats` — :class:`ReplicaStats`: the per-replica
  measured view (EWMA tok/s, queue depth, sliding-window p95 TTFT)
  each engine publishes and the router's online cost correction
  consumes.
"""
from repro.obs.registry import (PERCENTILES, Counter,       # noqa: F401
                                CountersView, Gauge, Histogram,
                                MetricsRegistry, RollingGauge,
                                percentile_block)
from repro.obs.stats import ReplicaStats                     # noqa: F401
from repro.obs.trace import (Tracer, traced_jit,             # noqa: F401
                             validate_chrome_trace)
