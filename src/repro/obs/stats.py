"""Per-replica measured serving statistics for online cost correction.

:class:`ReplicaStats` is the bridge between an engine's tick loop and
the router's cost model: every tick the engine feeds ``on_tick(now,
new_tokens, queue_depth)`` and every first token feeds
``observe_ttft``; the router reads the EWMA throughput, current queue
depth and sliding-window p95 TTFT through ``snapshot()`` and blends
them into ``replica_cost``'s static simulator estimate
(``cost_correction="online"``).

EWMA over per-tick instantaneous rates (``new_tokens / dt``) rather
than a cumulative average: the router must react to a replica that
*became* slow (noisy neighbor, thermal, bigger requests), and a
cumulative mean would take the whole history to move. All timestamps
come from the caller's clock (the engine's injected one), so tests
drive the statistics with synthetic time.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, Optional

import numpy as np


class ReplicaStats:
    """EWMA tok/s + queue depth + sliding-window TTFT percentiles.

    ``alpha`` is the EWMA weight of the newest per-tick rate sample;
    ``window`` bounds the TTFT reservoir (p95 over the last ``window``
    first tokens). Idle ticks (zero active slots and zero new tokens)
    are excluded from the throughput EWMA — an engine waiting for
    traffic is not a slow engine.
    """

    __slots__ = ("alpha", "window", "tok_per_s", "queue_depth",
                 "active_slots", "ticks", "transported", "_last_time",
                 "_ttfts", "_p95_override", "_ttft_count_override")

    def __init__(self, alpha: float = 0.2, window: int = 64):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.window = window
        self.tok_per_s: Optional[float] = None    # None until measured
        self.queue_depth: int = 0
        self.active_slots: int = 0
        self.ticks: int = 0
        # True once ingest() ran: this instance mirrors a REMOTE
        # engine's stats transported over the fabric rather than
        # observing a local tick loop
        self.transported: bool = False
        self._last_time: Optional[float] = None
        self._ttfts: Deque[float] = collections.deque(maxlen=window)
        self._p95_override: Optional[float] = None
        self._ttft_count_override: int = 0

    def on_tick(self, now: float, new_tokens: int, queue_depth: int,
                active_slots: int = 0):
        """One engine tick: ``new_tokens`` generated since the last
        call, current queue depth and busy slots."""
        self.ticks += 1
        self.queue_depth = int(queue_depth)
        self.active_slots = int(active_slots)
        last, self._last_time = self._last_time, now
        if last is None:
            return
        dt = now - last
        if dt <= 0:
            return                      # synthetic clocks may not advance
        if new_tokens == 0 and active_slots == 0:
            return                      # idle tick: no throughput signal
        rate = new_tokens / dt
        if self.tok_per_s is None:
            self.tok_per_s = rate
        else:
            self.tok_per_s = (self.alpha * rate
                              + (1.0 - self.alpha) * self.tok_per_s)

    def observe_ttft(self, ttft_s: float):
        self._ttfts.append(float(ttft_s))

    def ingest(self, snapshot: Dict):
        """Overwrite the measured state from a transported ``snapshot()``
        dict — the fabric controller's view of a remote engine's stats.

        The remote reservoir of raw TTFT samples never crosses the wire,
        only its p95; ``p95_ttft_s`` reports the transported value until
        a fresher snapshot lands. The blend inputs the router reads
        (``tok_per_s``, ``measured``, queue depth, active slots) carry
        over directly, so a Router over transported stats ranks exactly
        like one holding the engines in-process.
        """
        self.tok_per_s = snapshot.get("tok_per_s")
        self.queue_depth = int(snapshot.get("queue_depth") or 0)
        self.active_slots = int(snapshot.get("active_slots") or 0)
        self.ticks = int(snapshot.get("ticks") or 0)
        self._p95_override = snapshot.get("p95_ttft_s")
        self._ttft_count_override = int(snapshot.get("ttft_samples") or 0)
        self.transported = True

    @property
    def p95_ttft_s(self) -> Optional[float]:
        if self.transported:
            return self._p95_override
        if not self._ttfts:
            return None
        return float(np.percentile(np.asarray(self._ttfts), 95))

    @property
    def measured(self) -> bool:
        """Has at least one throughput sample landed?"""
        return self.tok_per_s is not None

    def snapshot(self) -> Dict:
        return {
            "tok_per_s": self.tok_per_s,
            "queue_depth": self.queue_depth,
            "active_slots": self.active_slots,
            "p95_ttft_s": self.p95_ttft_s,
            "ttft_samples": (self._ttft_count_override if self.transported
                            else len(self._ttfts)),
            "ticks": self.ticks,
            "transported": self.transported,
        }

    def __repr__(self):
        tps = "unmeasured" if self.tok_per_s is None \
            else f"{self.tok_per_s:.1f} tok/s"
        return (f"ReplicaStats({tps}, queue={self.queue_depth}, "
                f"ticks={self.ticks})")
