"""Explicit-clock span tracing exported as Chrome trace-event JSON.

A :class:`Tracer` records three kinds of timeline rows, all stamped
from an injected clock (the engine passes its own ``clock`` so tests
drive spans with synthetic timestamps and get byte-identical traces):

* **complete spans** (``ph="X"``) — the per-tick engine phases
  (admission, prefill dispatch, block dispatch, host sync, harvest)
  and ``compile:*`` spans from :func:`traced_jit`;
* **begin/end pairs** (``ph="B"``/``"E"``) — long-lived request
  lifecycle stages (queued → prefill → decode) that span many ticks,
  one lane (``tid``) per request so pairs never interleave;
* **instants** (``ph="i"``) — point events (first token, finish,
  jax trace markers).

``dump()`` writes ``{"traceEvents": [...]}`` with timestamps in
microseconds — the Chrome trace-event format Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` load directly;
``tools/trace_report.py`` renders the same file as a terminal summary.

A disabled tracer (``enabled=False``) is free: ``span()`` hands back a
shared no-op context manager and every record method returns before
touching the clock, so the engine can construct one unconditionally.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

# lane (tid) layout inside the single engine process (pid): the tick
# phases share lane 0, request lifecycles get REQUEST_LANE_BASE + rid
TICK_LANE = 0
REQUEST_LANE_BASE = 1000

_EVENT_PHASES = ("X", "B", "E", "i", "M", "C")


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Open complete-span: records an ``X`` event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_tid", "_args", "_t0")

    def __init__(self, tracer, name, cat, tid, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._tid = tid
        self._args = args
        self._t0 = None

    def __enter__(self):
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc):
        self._tracer.complete(self._name, self._t0, self._tracer.clock(),
                              cat=self._cat, tid=self._tid,
                              args=self._args)
        return False


class Tracer:
    """Span recorder with an injectable clock and a bounded buffer.

    ``max_events`` caps the in-memory buffer (a long-running engine
    must not grow without bound); events past the cap are counted in
    ``dropped`` and surfaced as an instant in the exported trace.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 enabled: bool = True, pid: int = 1,
                 process: str = "engine", max_events: int = 200_000):
        self.clock = clock
        self.enabled = enabled
        self.pid = pid
        self.events: List[Dict] = []
        self.dropped = 0
        self._max_events = max_events
        self._lane_names: Dict[int, str] = {}
        if enabled:
            self._meta("process_name", TICK_LANE, {"name": process})
            self.name_lane(TICK_LANE, "tick phases")

    # ------------------------------------------------------------ recording

    def _push(self, ev: Dict):
        if len(self.events) >= self._max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def _meta(self, name: str, tid: int, args: Dict):
        self._push({"name": name, "ph": "M", "ts": 0, "pid": self.pid,
                    "tid": tid, "args": args})

    def name_lane(self, tid: int, name: str):
        """Label a lane (Chrome thread_name metadata), once per tid."""
        if not self.enabled or tid in self._lane_names:
            return
        self._lane_names[tid] = name
        self._meta("thread_name", tid, {"name": name})

    def span(self, name: str, cat: str = "engine", tid: int = TICK_LANE,
             args: Optional[Dict] = None):
        """Context manager recording one complete (``X``) span."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, tid, args)

    def complete(self, name: str, t0: float, t1: float,
                 cat: str = "engine", tid: int = TICK_LANE,
                 args: Optional[Dict] = None):
        """Record a finished span from explicit begin/end timestamps."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X", "ts": t0 * 1e6,
              "dur": max(t1 - t0, 0.0) * 1e6, "pid": self.pid, "tid": tid}
        if args:
            ev["args"] = args
        self._push(ev)

    def begin(self, name: str, tid: int, cat: str = "request",
              args: Optional[Dict] = None):
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "B",
              "ts": self.clock() * 1e6, "pid": self.pid, "tid": tid}
        if args:
            ev["args"] = args
        self._push(ev)

    def end(self, name: str, tid: int, cat: str = "request"):
        if not self.enabled:
            return
        self._push({"name": name, "cat": cat, "ph": "E",
                    "ts": self.clock() * 1e6, "pid": self.pid,
                    "tid": tid})

    def instant(self, name: str, cat: str = "engine",
                tid: int = TICK_LANE, args: Optional[Dict] = None):
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self.clock() * 1e6, "pid": self.pid, "tid": tid}
        if args:
            ev["args"] = args
        self._push(ev)

    # ----------------------------------------------- request lifecycle sugar

    def request_lane(self, rid: int) -> int:
        tid = REQUEST_LANE_BASE + (rid if rid >= 0 else
                                   REQUEST_LANE_BASE - rid)
        self.name_lane(tid, f"req {rid}")
        return tid

    def req_begin(self, rid: int, stage: str,
                  args: Optional[Dict] = None):
        if not self.enabled:
            return
        self.begin(stage, self.request_lane(rid), args=args)

    def req_end(self, rid: int, stage: str):
        if not self.enabled:
            return
        self.end(stage, self.request_lane(rid))

    def req_instant(self, rid: int, name: str,
                    args: Optional[Dict] = None):
        if not self.enabled:
            return
        self.instant(name, cat="request", tid=self.request_lane(rid),
                     args=args)

    # -------------------------------------------------------------- export

    def to_chrome(self) -> Dict:
        """The Chrome trace-event JSON object (``dump()`` serializes
        exactly this)."""
        events = list(self.events)
        if self.dropped:
            events.append({"name": f"tracer dropped {self.dropped} events",
                           "cat": "tracer", "ph": "i", "s": "g", "ts": 0,
                           "pid": self.pid, "tid": TICK_LANE})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=float)
        return path


def traced_jit(fn: Callable, name: str,
               tracer: Optional[Tracer]) -> Callable:
    """Wrap a jitted callable so compilations surface as tracer spans.

    Compilation in jax happens synchronously inside the first call per
    input signature (execution then dispatches async), so timing a call
    whose program-cache size grew captures the trace+lower+compile cost
    as a ``compile:<name>`` span — the compile storms that were
    previously invisible. Detection uses the jit cache size when the
    callable exposes it (``_cache_size``) and falls back to
    first-call-per-wrapper (exact for the engine's fixed-shape
    programs). With tracing disabled the raw callable is returned —
    zero per-dispatch overhead.
    """
    if tracer is None or not tracer.enabled:
        return fn
    cache_size = getattr(fn, "_cache_size", None)
    state = {"called": False}

    def wrapped(*args, **kwargs):
        before = cache_size() if cache_size is not None else None
        t0 = tracer.clock()
        out = fn(*args, **kwargs)
        compiled = (cache_size() > before if cache_size is not None
                    else not state["called"])
        state["called"] = True
        if compiled:
            tracer.complete(f"compile:{name}", t0, tracer.clock(),
                            cat="compile")
        return out

    return wrapped


def validate_chrome_trace(data) -> List[str]:
    """Schema-check a Chrome trace-event object; returns error strings
    (empty = valid). Accepts the ``{"traceEvents": [...]}`` object form
    or a bare event list. Shared by ``tools/trace_report.py``, the
    serving smoke's ``--trace`` contract, and the obs tests."""
    errors: List[str] = []
    if isinstance(data, dict):
        events = data.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level object lacks a 'traceEvents' list"]
    elif isinstance(data, list):
        events = data
    else:
        return [f"trace must be an object or list, got {type(data).__name__}"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"event {i}: missing {key!r}")
        ph = ev.get("ph")
        if ph not in _EVENT_PHASES:
            errors.append(f"event {i}: unknown phase {ph!r}")
        if ph == "X" and not (isinstance(ev.get("dur"), (int, float))
                              and ev["dur"] >= 0):
            errors.append(f"event {i}: X event needs dur >= 0")
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event {i}: ts must be a number")
        if len(errors) > 20:
            errors.append("... (truncated)")
            break
    return errors
