"""Typed metrics: counters, gauges, histograms, rolling-window gauges.

``MetricsRegistry`` replaces the serving engine's raw ``counters``
dict with typed instruments while keeping the external schema
bit-compatible: :class:`CountersView` is a ``MutableMapping`` over the
registry's counters, so every pre-refactor call site
(``counters["ticks"] += 1``, ``dict(counters)``, iteration, equality,
reset-by-assignment) keeps working unchanged and
``metrics()["counters"]`` serializes to the identical plain dict.

Rolling-window gauges hold the last ``window`` ``(time, value)``
samples — the engine feeds one sample per tick (tok/s, queue depth,
batch occupancy, short-block rate), so their snapshots describe the
*recent* steady state rather than the whole run.

This module is also the canonical home of the percentile-block schema
every latency summary in the repo uses (``repro.serving.metrics``
re-exports it)::

    {"p50": .., "p90": .., "p95": .., "p99": .., "mean": .., "max": ..}

i.e. one key per entry of ``PERCENTILES = (50, 90, 95, 99)`` plus
``mean`` and ``max``; an empty sample yields ``{}`` (never NaNs).
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

PERCENTILES = (50, 90, 95, 99)


def percentile_block(values: Sequence[float],
                     ps: Sequence[int] = PERCENTILES) -> Dict[str, float]:
    """The canonical summary block of a sample; ``{}`` when empty.
    ``None`` entries are dropped (unmeasured timestamps)."""
    xs = np.asarray([v for v in values if v is not None], float)
    if xs.size == 0:
        return {}
    out = {f"p{p}": float(np.percentile(xs, p)) for p in ps}
    out["mean"] = float(xs.mean())
    out["max"] = float(xs.max())
    return out


class Counter:
    """Monotonic-by-convention integer counter (resettable for bench
    warmup)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: int = 0):
        self.name = name
        self.value = value

    def inc(self, n: int = 1):
        self.value += n

    def set(self, value: int):
        self.value = value

    def __repr__(self):
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Last-value-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: float = 0.0):
        self.name = name
        self.value = value

    def set(self, value: float):
        self.value = value

    def __repr__(self):
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Bounded sample reservoir summarized as the canonical percentile
    block. Keeps the most recent ``max_samples`` observations — serving
    histograms describe recent behavior, not unbounded history."""

    __slots__ = ("name", "_samples")

    def __init__(self, name: str, max_samples: int = 4096):
        self.name = name
        self._samples: Deque[float] = collections.deque(maxlen=max_samples)

    def observe(self, value: float):
        self._samples.append(float(value))

    def __len__(self):
        return len(self._samples)

    def summary(self) -> Dict[str, float]:
        return percentile_block(self._samples)

    def __repr__(self):
        return f"Histogram({self.name}, n={len(self._samples)})"


class RollingGauge:
    """Sliding window of the last ``window`` ``(time, value)`` samples.

    ``snapshot()`` reports the last value, the window mean, the window
    rate (``sum(values) / (t_last - t_first)`` — meaningful when values
    are per-sample increments like tokens-per-tick; ``None`` until two
    samples span nonzero time), and the sample count.
    """

    __slots__ = ("name", "window", "_samples")

    def __init__(self, name: str, window: int = 64):
        self.name = name
        self.window = window
        self._samples: Deque[Tuple[float, float]] = \
            collections.deque(maxlen=window)

    def observe(self, t: float, value: float):
        self._samples.append((float(t), float(value)))

    def __len__(self):
        return len(self._samples)

    @property
    def last(self) -> Optional[float]:
        return self._samples[-1][1] if self._samples else None

    def mean(self) -> Optional[float]:
        if not self._samples:
            return None
        return float(np.mean([v for _, v in self._samples]))

    def rate(self) -> Optional[float]:
        if len(self._samples) < 2:
            return None
        span = self._samples[-1][0] - self._samples[0][0]
        if span <= 0:
            return None
        # the first sample's value predates the window's time span
        return float(sum(v for _, v in list(self._samples)[1:]) / span)

    def snapshot(self) -> Dict[str, Optional[float]]:
        return {"last": self.last, "mean": self.mean(),
                "rate": self.rate(), "n": len(self._samples)}

    def __repr__(self):
        return f"RollingGauge({self.name}, n={len(self._samples)})"


class CountersView(collections.abc.MutableMapping):
    """Dict-compatible facade over a registry's counters.

    Supports everything the pre-refactor raw dict was used for:
    ``view[k] += 1``, assignment (creates the counter on first write),
    iteration in creation order, ``dict(view)``, ``==`` against dicts
    and other views, and a dict-shaped ``repr``.
    """

    __slots__ = ("_registry",)

    def __init__(self, registry: "MetricsRegistry"):
        self._registry = registry

    def __getitem__(self, name: str) -> int:
        return self._registry._counters[name].value

    def __setitem__(self, name: str, value: int):
        self._registry.counter(name).set(value)

    def __delitem__(self, name: str):
        del self._registry._counters[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._registry._counters)

    def __len__(self) -> int:
        return len(self._registry._counters)

    def __eq__(self, other) -> bool:
        if isinstance(other, (dict, CountersView)):
            return dict(self) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def __repr__(self):
        return repr(dict(self))


class MetricsRegistry:
    """Named typed instruments; ``get-or-create`` accessors so call
    sites never race on registration order."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._rollings: Dict[str, RollingGauge] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, max_samples)
        return h

    def rolling(self, name: str, window: int = 64) -> RollingGauge:
        r = self._rollings.get(name)
        if r is None:
            r = self._rollings[name] = RollingGauge(name, window)
        return r

    def counters_view(self) -> CountersView:
        return CountersView(self)

    def snapshot(self) -> Dict:
        """Everything, as plain JSON-ready dicts."""
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {k: h.summary()
                           for k, h in self._histograms.items()},
            "rolling": {k: r.snapshot()
                        for k, r in self._rollings.items()},
        }
